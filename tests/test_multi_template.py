"""Multi-template mega-DAG consolidation (DESIGN.md §8.1).

Covers the consolidate_multi edge cases: colliding node ids across
templates, zero cross-template overlap (degrades to disjoint
subgraphs), the same template submitted twice (matches single-template
consolidation), empty template slices (the n_logical == 0 div-zero
regression), epoch interleaving, the cost model's cross-template warm
alias, and bitwise-identical temp-0 outputs for consolidated-multi vs
per-template-serial through REAL engines.
"""
import pytest

from repro.core import (CostModel, EpochDPSolver, HARDWARE, PAPER_MODELS,
                        SolverConfig, consolidate, consolidate_multi,
                        parse_workflow)
from repro.core.state import WorkerContext
from repro.runtime.coordinator import BatchState
from repro.workloads import build_mixed_workload

WF_A = {"name": "A", "nodes": [
    {"id": "a", "type": "llm", "model": "qwen3-14b",
     "prompt": "Alpha $p with {{sql: SELECT x FROM t WHERE k='$p'}}"},
    {"id": "b", "type": "llm", "model": "qwen3-14b", "prompt": "Blend ${a}"},
]}
# SAME node ids as WF_A, different template; shares WF_A's SQL template
WF_SHARED = {"name": "S", "nodes": [
    {"id": "a", "type": "llm", "model": "qwen3-32b",
     "prompt": "Sigma $p via {{sql: SELECT x FROM t WHERE k='$p'}}"},
    {"id": "b", "type": "llm", "model": "qwen3-32b", "prompt": "Sum ${a}"},
]}
# SAME node ids, zero overlap with WF_A (different table/params)
WF_B = {"name": "B", "nodes": [
    {"id": "a", "type": "llm", "model": "qwen3-32b",
     "prompt": "Beta $q via {{sql: SELECT y FROM u WHERE j='$q'}}"},
    {"id": "b", "type": "llm", "model": "qwen3-32b", "prompt": "Mix ${a}"},
]}


def _cm(g, cons):
    return CostModel(g, HARDWARE["h200"], PAPER_MODELS,
                     batch_sizes={n: len(cons.macro(n).bindings)
                                  for n in g.nodes},
                     warm_aliases=cons.warm_aliases())


def _plan(g, cons, workers):
    return EpochDPSolver(g.llm_dag(), _cm(g, cons),
                         SolverConfig(num_workers=workers)).solve()


# ---------------------------------------------------------------- structure
def test_namespacing_keeps_colliding_ids_distinct():
    ga, gs = parse_workflow(WF_A), parse_workflow(WF_SHARED)
    mc = consolidate_multi([(ga, [{"p": "x"}]), (gs, [{"p": "x"}])])
    g = mc.template
    # both templates define "a"/"b"/"a__sql0" — all survive, namespaced
    for nid in ("t0/a", "t1/a", "t0/b", "t1/b", "t0/a__sql0", "t1/a__sql0"):
        assert nid in g.nodes
    assert mc.template_of["t0/a"] == 0 and mc.template_of["t1/a"] == 1
    # upstream refs were rewritten into the namespace
    assert "${t1/a}" in g.nodes["t1/b"].prompt
    # each namespaced node serves exactly its template's query slice
    qm = mc.queries_map()
    assert qm["t0/a"] == [0] and qm["t1/a"] == [1]
    # the shared rendered SQL coalesced across templates
    xt = mc.cross_template_summary()
    assert xt["cross_template_deduped"] == 1
    assert mc.physical_signatures("t0/a__sql0") and \
        not mc.physical_signatures("t1/a__sql0")


def test_identical_template_twice_matches_single_consolidate():
    g = parse_workflow(WF_A)
    b1 = [{"p": "x"}, {"p": "y"}]
    b2 = [{"p": "x"}]
    mc = consolidate_multi([(g, b1), (g, b2)])
    single = consolidate(g, b1 + b2)
    assert mc.n_queries == single.n_queries
    for base in g.nodes:
        merged_unique = set(mc.macro(f"t0/{base}").unique_signatures) \
            | set(mc.macro(f"t1/{base}").unique_signatures)
        assert len(merged_unique) == single.macro(base).n_unique, base
        # physical executions across BOTH namespaced copies of a tool
        # node collapse to the single-template count
        if not g.nodes[base].is_llm():
            phys = len(mc.physical_signatures(f"t0/{base}")) \
                + len(mc.physical_signatures(f"t1/{base}"))
            assert phys == len(single.physical_signatures(base)), base
    # identical static LLM specs became warm aliases
    assert "t1/a" in mc.warm_aliases()["t0/a"]


def test_zero_overlap_degrades_to_disjoint_sum():
    """No shared signatures -> the mega-DAG is two disjoint islands and
    its plan costs the sum of the per-template plans (up to the shared
    worker's model-eviction term and per-epoch overhead granularity);
    with more workers the merged plan is strictly cheaper."""
    ga, gb = parse_workflow(WF_A), parse_workflow(WF_B)
    ba = [{"p": "x"}, {"p": "y"}]
    bb = [{"q": "u"}, {"q": "v"}]
    mc = consolidate_multi([(ga, ba), (gb, bb)])
    assert mc.cross_template_summary()["cross_template_deduped"] == 0
    assert mc.cross_template_summary()["merged_signatures"] == 0
    assert mc.warm_aliases() == {}
    serial = {w: _plan(ga, consolidate(ga, ba), w).predicted_cost
              + _plan(gb, consolidate(gb, bb), w).predicted_cost
              for w in (1, 2)}
    multi = {w: _plan(mc.template, mc, w).predicted_cost for w in (1, 2)}
    assert abs(multi[1] - serial[1]) < 0.15        # eviction + overhead
    assert multi[2] < serial[2]                    # parallelism wins


def test_empty_template_slice_no_division_by_zero():
    """Regression pin: a macro-node with n_logical == 0 (empty bindings
    slice) must not break the dedup reporting, and its nodes are
    macro-complete from birth."""
    ga, gb = parse_workflow(WF_A), parse_workflow(WF_B)
    mc = consolidate_multi([(ga, []), (gb, [{"q": "u"}])])
    assert mc.macro("t0/a").n_logical == 0
    assert mc.static_dedup_ratio("t0/a") == 1.0    # not 0.0, not ZeroDiv
    summary = mc.coalescing_summary()
    assert summary["t0/a"] == {"logical": 0, "unique": 0, "physical": 0,
                               "dedup_ratio": 1.0}
    # merged-away macro (identical template twice): unique > 0, owned 0
    mc2 = consolidate_multi([(ga, [{"p": "x"}]), (ga, [{"p": "x"}])])
    row = mc2.coalescing_summary()["t1/a__sql0"]
    assert row["unique"] == 1 and row["physical"] == 0
    assert 0.0 < mc2.static_dedup_ratio("t1/a__sql0") <= 1.0
    # runtime: zero-query nodes are done at birth, others are not
    state = BatchState(mc.template, mc.n_queries,
                       queries_of=mc.queries_map())
    assert "t0/a" in state.macro_done and "t1/a" not in state.macro_done


# ---------------------------------------------------------------- planning
def test_epoch_plan_interleaves_templates():
    from benchmarks.common import halo_plan, interleaved_epochs, setup_multi
    g, mc, _, _ = setup_multi(6, seed=0, parts=("wd", "wt"))
    plan = halo_plan(g, mc, workers=2)
    assert interleaved_epochs(plan, mc) >= 1
    # every node is planned exactly once
    assert sorted(n for n, _ in plan.node_order()) == sorted(
        g.llm_dag().node_ids)


def test_warm_alias_gives_cross_template_prefix_credit():
    g = parse_workflow(WF_A)
    mc = consolidate_multi([(g, [{"p": "x"}]), (g, [{"p": "x"}])])
    cm = _cm(mc.template, mc)
    spec = mc.template.nodes["t1/b"]
    # context warm on the OTHER template's copy of the parent
    warm = WorkerContext(model=spec.model, warm=("t0/a",))
    cold = WorkerContext(model=spec.model, warm=())
    assert cm.t_infer(spec, warm, ["t1/a"]) < cm.t_infer(spec, cold,
                                                         ["t1/a"])


def test_colliding_ids_with_different_specs_never_merge():
    """Regression pin: signatures of upstream-dependent nodes carry the
    spec identity, so a colliding local id ('t' in two unrelated
    templates) with different op/args must NOT dedup across templates."""
    t1 = parse_workflow({"name": "T1", "nodes": [
        {"id": "a", "type": "llm", "model": "qwen3-14b", "prompt": "Go $p"},
        {"id": "t", "type": "tool", "op": "sql",
         "args": "SELECT x FROM movies WHERE k=${a}", "deps": ["a"]}]})
    t2 = parse_workflow({"name": "T2", "nodes": [
        {"id": "a", "type": "llm", "model": "qwen3-14b", "prompt": "Run $p"},
        {"id": "t", "type": "tool", "op": "http",
         "args": "GET http://api/other?ref=${a}", "deps": ["a"]}]})
    mc = consolidate_multi([(t1, [{"p": "x"}]), (t2, [{"p": "x"}])])
    assert mc.physical_signatures("t1/t")          # still owns its run
    assert mc.cross_template_summary()["cross_template_deduped"] == 0
    # IDENTICAL tool spec over DIFFERENT parents must not merge either:
    # ${a} renders different upstream outputs at runtime
    t3 = parse_workflow({"name": "T3", "nodes": [
        {"id": "a", "type": "llm", "model": "qwen3-14b", "prompt": "Go $p"},
        {"id": "t", "type": "tool", "op": "sql",
         "args": "SELECT x FROM movies WHERE k=${a}", "deps": ["a"]}]})
    t4 = parse_workflow({"name": "T4", "nodes": [
        {"id": "a", "type": "llm", "model": "qwen3-14b", "prompt": "No $p"},
        {"id": "t", "type": "tool", "op": "sql",
         "args": "SELECT x FROM movies WHERE k=${a}", "deps": ["a"]}]})
    mc2 = consolidate_multi([(t3, [{"p": "x"}]), (t4, [{"p": "x"}])])
    assert mc2.physical_signatures("t1/t")
    assert mc2.cross_template_summary()["cross_template_deduped"] == 0
    # ...but two copies of the SAME template still dedup
    mc3 = consolidate_multi([(t3, [{"p": "x"}]), (t3, [{"p": "x"}])])
    assert not mc3.physical_signatures("t1/t")
    assert mc3.cross_template_summary()["cross_template_deduped"] == 1


def test_warm_alias_requires_identical_upstream_lineage():
    """Regression pin: 'Summarize ${x}' over DIFFERENT x templates must
    not become a warm alias — only a fully identical upstream subtree
    shares radix pages."""
    def wf(name, research):
        return parse_workflow({"name": name, "nodes": [
            {"id": "x", "type": "llm", "model": "qwen3-14b",
             "prompt": research},
            {"id": "b", "type": "llm", "model": "qwen3-14b",
             "prompt": "Summarize ${x}"}]})
    mc = consolidate_multi([(wf("U1", "Research cats $p"), [{"p": "x"}]),
                            (wf("U2", "Research dogs $p"), [{"p": "x"}])])
    assert "t0/b" not in mc.warm_aliases()
    same = wf("U1", "Research cats $p")
    mc2 = consolidate_multi([(same, [{"p": "x"}]), (same, [{"p": "y"}])])
    assert "t1/b" in mc2.warm_aliases()["t0/b"]


def test_mixed_workload_builder():
    batches, db = build_mixed_workload(7, seed=0)
    assert db == "finewiki"
    assert [len(b) for _, b in batches] == [3, 2, 2]   # remainder first
    with pytest.raises(ValueError):
        build_mixed_workload(4, parts=("w1", "w3"))    # imdb vs finewiki
    mc = consolidate_multi(batches)
    assert mc.cross_template_summary()["cross_template_deduped"] >= 1


def test_simulated_multi_beats_per_template_serial():
    from benchmarks.common import run_multi_sim_ab
    rep, serial_s, plan, mc = run_multi_sim_ab(48, workers=3)
    assert rep.makespan < serial_s
    # the simulated run completed every namespaced node
    llm_nodes = {r.node for r in rep.records if r.kind == "llm"}
    assert llm_nodes == set(mc.template.llm_nodes())


def test_empty_slice_costs_nothing_in_simulator():
    """Regression pin: an empty template slice's LLM macro-nodes must
    not be simulated as batch-1 inferences with phantom model switches
    (they would inflate the consolidated-multi arm)."""
    from benchmarks.common import make_cm
    from repro.runtime import SimulatedProcessor
    ga, gb = parse_workflow(WF_A), parse_workflow(WF_B)
    binds = [{"p": "x"}, {"p": "y"}]
    mc = consolidate_multi([(ga, binds), (gb, [])])
    plan = _plan(mc.template, mc, 2)
    rep = SimulatedProcessor(mc.template, make_cm(mc.template, mc),
                             2).run(mc, plan)
    for r in rep.records:
        if r.node.startswith("t1/") and r.kind == "llm":
            assert r.batch == 0 and (r.end - r.start) < 0.01, r
    # the run is priced like template A alone (within jitter/overhead)
    ca = consolidate(ga, binds)
    alone = SimulatedProcessor(ga, make_cm(ga, ca), 2).run(
        ca, _plan(ga, ca, 2))
    assert rep.makespan < alone.makespan * 1.2 + 0.1


def test_migrator_probes_warm_alias_lineage():
    """Regression pin: the KVMigrator must probe warm-alias node ids
    when collecting lineage prompts — the cost model prices peer
    aliases as donors, so the runtime has to look for them."""
    from repro.runtime.migrate import KVMigrator
    g = parse_workflow(WF_A)
    mc = consolidate_multi([(g, [{"p": "x"}]), (g, [{"p": "x"}])])
    cm = _cm(mc.template, mc)

    class _Host:
        def prompts_for(self, nid):
            return {"t0/a": [(1, 2)], "t0/b": [(3, 4)]}.get(nid, [])

    mig = KVMigrator(mc.template, [_Host()], cost_model=cm)
    prompts = mig._lineage_prompts("t1/b", _Host())
    assert (1, 2) in prompts and (3, 4) in prompts   # via aliases


def test_lineage_digest_linear_on_fanin_heavy_template():
    """Regression pin: consolidating a deep diamond/fan-in template must
    stay O(nodes) — a materialized nested lineage key would be O(2^k)."""
    nodes = [{"id": "x0", "type": "llm", "model": "qwen3-14b",
              "prompt": "Seed $p"}]
    for i in range(1, 29):                       # 28 diamond levels
        prev = f"x{i - 1}"
        nodes.append({"id": f"a{i}", "type": "llm", "model": "qwen3-14b",
                      "prompt": f"L ${{{prev}}}"})
        nodes.append({"id": f"b{i}", "type": "llm", "model": "qwen3-14b",
                      "prompt": f"R ${{{prev}}}"})
        nodes.append({"id": f"x{i}", "type": "llm", "model": "qwen3-14b",
                      "prompt": f"Join ${{a{i}}} ${{b{i}}}"})
    g = parse_workflow({"name": "diamond", "nodes": nodes})
    mc = consolidate_multi([(g, [{"p": "x"}]), (g, [{"p": "y"}])])
    # two copies of the same template alias node-for-node
    assert "t1/x28" in mc.warm_aliases()["t0/x28"]


# ----------------------------------------------------------- real engines
def test_real_multi_vs_per_template_serial_bitwise():
    """The acceptance pin: one mega-DAG run through REAL engines produces
    bitwise-identical temp-0 outputs to running each template's slice
    separately, while reporting the cross-template coalescing stats."""
    from benchmarks.common import (halo_plan, make_real_multi_processor,
                                   smoke_models_for)
    from repro.runtime import RealProcessor
    from repro.workloads.datagen import build_database
    from repro.workloads.tools import ToolRuntime
    proc, g, mc, batches, plan, db = make_real_multi_processor(
        4, workers=2, decode_cap=3, parts=("wd", "wt"))
    rep = proc.run(mc, plan)
    assert set(rep.coalesce_stats) >= {"cross_template_merged_tasks",
                                       "cross_template_merged_requests"}
    multi_results = rep.results()
    # every (query, node) of every template slice produced a result
    assert len(multi_results) == sum(
        len(tb) * len(tg.nodes) for tg, tb in batches)

    offsets, off = [], 0
    for _, tb in batches:
        offsets.append(off)
        off += len(tb)
    for k, (tg, tb) in enumerate(batches):
        cons = consolidate(tg, tb)
        r = RealProcessor(
            tg, smoke_models_for(tg),
            ToolRuntime(build_database(db), latency_scale=0.0),
            num_workers=2, decode_cap=3).run(
                cons, halo_plan(tg, cons, workers=2))
        for key, val in r.results().items():
            q, node = key.split(":", 1)
            mkey = f"{int(q) + offsets[k]}:t{k}/{node}"
            assert multi_results[mkey] == val, mkey


# ------------------------------------------------------------------- docs
def test_check_docs_passes():
    """The CI docs job's checker is clean on the tree as committed."""
    import importlib.util
    import pathlib
    path = pathlib.Path(__file__).resolve().parent.parent / "tools" \
        / "check_docs.py"
    spec = importlib.util.spec_from_file_location("check_docs", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert mod.main() == 0
