"""Engine: shared-prefix generation equivalence, coalescing, paged cache."""
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.engine.engine import InferenceEngine
from repro.engine.kvcache import PagedKVCache
from repro.engine.tokenizer import detokenize, tokenize


def test_shared_prefix_equals_naive_transformer():
    cfg = get_smoke("qwen3-1.7b")
    prefix = list(range(10, 20))
    prompts = [prefix + [30 + i] for i in range(4)]
    o1 = InferenceEngine(cfg, seed=0, enable_prefix_sharing=True).generate(
        prompts, max_new_tokens=6)
    o2 = InferenceEngine(cfg, seed=0, enable_prefix_sharing=False).generate(
        prompts, max_new_tokens=6)
    assert o1 == o2


def test_shared_prefix_saves_prefill_work():
    cfg = get_smoke("qwen3-1.7b")
    prefix = list(range(10, 26))
    prompts = [prefix + [40 + i] for i in range(4)]
    eng = InferenceEngine(cfg, seed=0, enable_prefix_sharing=True)
    eng.generate(prompts, max_new_tokens=2)
    assert eng.stats.prefill_tokens_saved == len(prefix) * 3
    assert eng.stats.prefill_tokens < 4 * len(prompts[0])


def test_engine_coalesces_exact_duplicates():
    cfg = get_smoke("llama3.2-3b")
    p = list(range(5, 15))
    eng = InferenceEngine(cfg, seed=0)
    outs = eng.generate([p, p, p], max_new_tokens=4)
    assert outs[0] == outs[1] == outs[2]
    assert eng.stats.coalesced_requests == 2


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "xlstm-350m"])
def test_recurrent_state_snapshot_sharing_close(arch):
    """Recurrent archs share state snapshots; logits match to fp noise."""
    import jax, jax.numpy as jnp
    from repro.engine.models import build_model
    cfg = get_smoke(arch).replace(dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.arange(10, 24, dtype=jnp.int32)[None, :]
    full, _ = model.prefill(params, toks)
    lg, cache = model.prefill(params, toks[:, :10])
    cache = model.extend_cache(cache, 8)
    for t in range(10, 14):
        lg, cache = model.decode_step(params, toks[:, t], cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full),
                               atol=1e-4, rtol=1e-4)


def test_paged_kv_cache_share_and_cow():
    pc = PagedKVCache(num_layers=2, num_pages=16, page_size=4, kv_heads=2,
                      head_dim=8)
    rng = np.random.default_rng(0)
    k, v = rng.normal(size=(2, 10, 2, 8)), rng.normal(size=(2, 10, 2, 8))
    s1 = pc.add_sequence(k, v)
    gk, gv = pc.gather(s1)
    np.testing.assert_allclose(gk, k)
    # share the first 2 full pages (8 tokens)
    k2, v2 = rng.normal(size=(2, 5, 2, 8)), rng.normal(size=(2, 5, 2, 8))
    s2 = pc.add_sequence(k2, v2, shared_from=s1, shared_len=8)
    gk2, _ = pc.gather(s2)
    np.testing.assert_allclose(gk2[:, :8], k[:, :8])
    np.testing.assert_allclose(gk2[:, 8:13], k2)
    assert pc.tokens_reused == 8
    # appending to s2 must not corrupt s1 (copy-on-write partial pages)
    pc.append_token(s2, np.ones((2, 2, 8)), np.ones((2, 2, 8)))
    np.testing.assert_allclose(pc.gather(s1)[0], k)
    pc.free_sequence(s1)
    pc.free_sequence(s2)
    assert pc.pages_in_use == 0


def test_paged_cache_oom_raises():
    pc = PagedKVCache(num_layers=1, num_pages=2, page_size=4, kv_heads=1,
                      head_dim=4)
    rng = np.random.default_rng(0)
    pc.add_sequence(rng.normal(size=(1, 8, 1, 4)),
                    rng.normal(size=(1, 8, 1, 4)))
    with pytest.raises(MemoryError):
        pc.add_sequence(rng.normal(size=(1, 8, 1, 4)),
                        rng.normal(size=(1, 8, 1, 4)))


def test_tokenizer_deterministic_roundtrippable():
    t1 = tokenize("revenue dropped in us market", 5000)
    t2 = tokenize("revenue dropped in us market", 5000)
    assert t1 == t2
    assert t1 != tokenize("revenue dropped in eu market", 5000)
    assert detokenize(t1) == detokenize(t2)
