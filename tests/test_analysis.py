"""Static-analysis checkers (tools/analysis) + debugsync runtime verifier.

Covers DESIGN.md §11: the fixture corpus under tests/fixtures/analysis/,
the tree-is-clean gate the CI ``analysis`` job enforces, a seeded
in-memory violation smoke, the CLI exit codes, the REPRO_DEBUG_SYNC
lock-order verifier, and regressions for the concurrency fixes the
checkers surfaced.
"""
import pathlib
import subprocess
import sys
import threading
import time

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.analysis import DEFAULT_SRC, run  # noqa: E402
from repro import debugsync  # noqa: E402

FX = REPO / "tests" / "fixtures" / "analysis"
# intentionally absent -> empty allowlist (run() must NOT fall back to
# the real one when analyzing a fixture tree)
NO_ALLOW = FX / "no-allowlist.toml"


def _fixture(case, allow=None, roots=("Engine._step",)):
    return run(root=FX / case,
               allowlist=allow if allow is not None else NO_ALLOW,
               roots=roots)


# ---------------------------------------------------------------- tree gate


def test_tree_is_clean_strict():
    res = run()
    assert res.ok(strict=True), "\n".join(
        [f.render() for f in res.findings + res.config_errors]
        + res.allow_errors + [f"UNUSED {e.site}" for e in res.unused])


def test_tree_counts_are_sane():
    c = run().counts
    assert c["named_locks"] >= 10
    assert c["guarded_attrs"] >= 50
    assert c["jit_sites"] >= 10
    assert c["hot_path_functions"] >= 20
    assert c["findings"] == 0


# ---------------------------------------------------------------- fixtures


def test_unguarded_write_is_caught():
    res = _fixture("locks_bad")
    assert not res.ok()
    [f] = res.findings
    assert f.checker == "locks"
    assert f.qualname == "Counter.bump_racy"
    assert "guarded-by Counter.lock" in f.message


def test_guarded_write_is_clean():
    res = _fixture("locks_good")
    assert res.ok(strict=True)
    assert res.findings == []


def test_lock_order_cycle_is_caught():
    res = _fixture("locks_cycle")
    assert not res.ok()
    [f] = res.findings
    assert f.checker == "locks" and f.symbol == "cycle"
    assert "Pair.a -> Pair.b" in f.message
    assert "Pair.b -> Pair.a" in f.message


def test_unbucketed_jit_arg_is_caught():
    res = _fixture("jit_bad")
    assert not res.ok()
    [f] = res.findings
    assert f.checker == "jit" and f.symbol == "_step"
    assert "bucketing" in f.message


def test_bucketed_jit_arg_is_clean():
    res = _fixture("jit_good")
    assert res.ok(strict=True)
    assert res.findings == []


def test_hot_path_sync_is_caught():
    res = _fixture("hostsync_bad")
    assert not res.ok()
    [f] = res.findings
    assert f.checker == "hostsync" and f.symbol == "int"
    assert f.qualname == "Engine._step"


def test_allowlisted_sync_passes_and_counts():
    res = _fixture("hostsync_allowed",
                   allow=FX / "hostsync_allowed" / "allow.toml")
    assert res.ok(strict=True)
    assert len(res.suppressed) == 1
    assert res.counts["syncs_allowed"] == 1


def test_allowlist_entry_without_reason_is_an_error(tmp_path):
    bad = tmp_path / "allow.toml"
    bad.write_text('[[allow]]\nchecker = "hostsync"\n'
                   'site = "engine.py:Engine._step:int"\n')
    res = _fixture("hostsync_allowed", allow=bad)
    assert not res.ok()
    assert res.allow_errors


# ------------------------------------------------------- seeded violation


def test_seeded_violation_is_caught():
    """Break the tree in-memory: a method touching a guarded attr with
    no lock held must turn the clean run red."""
    source = (DEFAULT_SRC / "engine" / "engine.py").read_text()
    # keep the '# runs-on: engine-loop' comment glued to _run_loop —
    # inserting between them would re-target the annotation
    needle = "\n    # runs-on: engine-loop\n    def _run_loop"
    assert needle in source
    evil = ("\n    def _evil(self):\n"
            "        return len(self._pending)\n" + needle)
    res = run(override={"engine/engine.py":
                        source.replace(needle, evil, 1)})
    assert not res.ok()
    assert any(f.checker == "locks" and f.qualname.endswith("._evil")
               for f in res.findings)


# ------------------------------------------------------------------- CLI


def test_cli_strict_exits_zero_on_tree():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.analysis", "--strict"],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_cli_exits_nonzero_on_violating_fixture():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.analysis", "--strict",
         "--root", str(FX / "locks_bad"),
         "--allowlist", str(NO_ALLOW)],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode != 0
    assert "bump_racy" in proc.stdout


# ------------------------------------------------- debugsync runtime layer


def test_named_lock_disabled_is_plain_lock(monkeypatch):
    monkeypatch.delenv("REPRO_DEBUG_SYNC", raising=False)
    lk = debugsync.named_lock("TestPlain.lk")
    assert isinstance(lk, type(threading.Lock()))
    assert isinstance(debugsync.named_condition("TestPlain.cv"),
                      threading.Condition)


def test_lock_order_inversion_raises(monkeypatch):
    monkeypatch.setenv("REPRO_DEBUG_SYNC", "1")
    a = debugsync.named_lock("TestInv.a")
    b = debugsync.named_lock("TestInv.b")
    with a:
        with b:
            pass
    with pytest.raises(debugsync.LockOrderError):
        with b:
            with a:
                pass
    assert debugsync.registry().held() == []


def test_reentrant_same_name_is_not_an_inversion(monkeypatch):
    monkeypatch.setenv("REPRO_DEBUG_SYNC", "1")
    cv = debugsync.named_condition("TestReent.cv")
    with cv:
        with cv:
            pass
    assert debugsync.registry().held() == []


def test_condition_wait_repushes_held_stack(monkeypatch):
    monkeypatch.setenv("REPRO_DEBUG_SYNC", "1")
    cv = debugsync.named_condition("TestWait.cv")
    ready, held_after_wait = [], []

    def waiter():
        with cv:
            while not ready:
                cv.wait(timeout=5)
            held_after_wait.extend(debugsync.registry().held())

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    with cv:
        ready.append(1)
        cv.notify()
    t.join(timeout=5)
    assert not t.is_alive()
    assert "TestWait.cv" in held_after_wait
    assert debugsync.registry().held() == []


# -------------------------------------------- regressions (checker finds)


def test_batch_state_is_macro_done_locked_view():
    from repro.core import consolidate
    from repro.runtime.coordinator import BatchState
    from repro.workloads import build_workload

    g, bindings, _ = build_workload("w+", 4, seed=0)
    consolidate(g, bindings)
    st = BatchState(g, 4)
    assert not st.is_macro_done("draft")
    for q in range(4):
        st.set_result(q, "draft", f"r{q}")
    assert st.is_macro_done("draft")


def test_checkpoint_batch_size_mismatch_raises(tmp_path):
    from repro.runtime.checkpoint import (load_batch_state,
                                          save_batch_state)
    from repro.runtime.coordinator import BatchState
    from repro.workloads import build_workload

    g, _, _ = build_workload("w+", 4, seed=0)
    st = BatchState(g, 4)
    st.set_result(0, "draft", "r0")
    p = str(tmp_path / "ck.json")
    save_batch_state(st, p)
    with pytest.raises(ValueError, match="different batch size"):
        load_batch_state(BatchState(g, 3), p)
