"""Static-analysis checkers (tools/analysis) + debugsync runtime verifier.

Covers DESIGN.md §11: the fixture corpus under tests/fixtures/analysis/,
the tree-is-clean gate the CI ``analysis`` job enforces, a seeded
in-memory violation smoke, the CLI exit codes, the REPRO_DEBUG_SYNC
lock-order verifier, and regressions for the concurrency fixes the
checkers surfaced.
"""
import pathlib
import subprocess
import sys
import threading
import time

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.analysis import DEFAULT_SRC, run  # noqa: E402
from repro import debugsync  # noqa: E402

FX = REPO / "tests" / "fixtures" / "analysis"
# intentionally absent -> empty allowlist (run() must NOT fall back to
# the real one when analyzing a fixture tree)
NO_ALLOW = FX / "no-allowlist.toml"


def _fixture(case, allow=None, roots=("Engine._step",)):
    return run(root=FX / case,
               allowlist=allow if allow is not None else NO_ALLOW,
               roots=roots)


# ---------------------------------------------------------------- tree gate


def test_tree_is_clean_strict():
    res = run()
    assert res.ok(strict=True), "\n".join(
        [f.render() for f in res.findings + res.config_errors]
        + res.allow_errors + [f"UNUSED {e.site}" for e in res.unused])


def test_tree_counts_are_sane():
    c = run().counts
    assert c["named_locks"] >= 10
    assert c["guarded_attrs"] >= 50
    assert c["jit_sites"] >= 10
    assert c["hot_path_functions"] >= 20
    assert c["findings"] == 0


# ---------------------------------------------------------------- fixtures


def test_unguarded_write_is_caught():
    res = _fixture("locks_bad")
    assert not res.ok()
    [f] = res.findings
    assert f.checker == "locks"
    assert f.qualname == "Counter.bump_racy"
    assert "guarded-by Counter.lock" in f.message


def test_guarded_write_is_clean():
    res = _fixture("locks_good")
    assert res.ok(strict=True)
    assert res.findings == []


def test_lock_order_cycle_is_caught():
    res = _fixture("locks_cycle")
    assert not res.ok()
    [f] = res.findings
    assert f.checker == "locks" and f.symbol == "cycle"
    assert "Pair.a -> Pair.b" in f.message
    assert "Pair.b -> Pair.a" in f.message


def test_unbucketed_jit_arg_is_caught():
    res = _fixture("jit_bad")
    assert not res.ok()
    [f] = res.findings
    assert f.checker == "jit" and f.symbol == "_step"
    assert "bucketing" in f.message


def test_bucketed_jit_arg_is_clean():
    res = _fixture("jit_good")
    assert res.ok(strict=True)
    assert res.findings == []


def test_hot_path_sync_is_caught():
    res = _fixture("hostsync_bad")
    assert not res.ok()
    [f] = res.findings
    assert f.checker == "hostsync" and f.symbol == "int"
    assert f.qualname == "Engine._step"


def test_allowlisted_sync_passes_and_counts():
    res = _fixture("hostsync_allowed",
                   allow=FX / "hostsync_allowed" / "allow.toml")
    assert res.ok(strict=True)
    assert len(res.suppressed) == 1
    assert res.counts["syncs_allowed"] == 1


def test_devmem_violations_are_caught():
    res = _fixture("devmem_bad")
    assert not res.ok()
    symbols = sorted(f.symbol for f in res.findings
                     if f.checker == "devmem")
    assert symbols == ["d2h", "dtype", "h2d-loop", "use-after-donate"]
    uad = next(f for f in res.findings if f.symbol == "use-after-donate")
    assert "pool.k" in uad.message and "rebound" in uad.message


def test_devmem_disciplined_tree_is_clean():
    res = _fixture("devmem_good")
    assert res.ok(strict=True)
    assert res.findings == []
    assert res.counts["memspace_attrs"] == 4
    assert res.counts["donate_sites"] == 1


def test_kernel_contract_violations_are_caught():
    res = _fixture("kernel_bad")
    assert not res.ok()
    symbols = [f.symbol for f in res.findings if f.checker == "kernel"]
    assert symbols.count("triple") == 2          # ops.py + ref.py missing
    assert "blockspec-divide" in symbols
    assert "grid-arity" in symbols
    assert "vmem-budget" in symbols
    vb = next(f for f in res.findings if f.symbol == "vmem-budget")
    # the static estimate is exact at the annotated bindings
    assert "6.00 MiB" in vb.message and "0.50 MiB" in vb.message


def test_kernel_contract_clean_package_passes():
    res = _fixture("kernel_good")
    assert res.ok(strict=True)
    assert res.findings == []
    assert res.counts["kernels_checked"] == 1
    assert res.counts["vmem_budgets"] == 1


def test_units_mismatches_are_caught():
    res = _fixture("units_bad")
    assert not res.ok()
    msgs = [f.message for f in res.findings if f.checker == "units"]
    assert any("@kv bytes priced over the @host path" in m
               for m in msgs)
    assert any("incompatible terms" in m for m in msgs)


def test_units_sound_tree_is_clean():
    res = _fixture("units_good")
    assert res.ok(strict=True)
    assert res.findings == []
    assert res.counts["unit_fields"] >= 6
    assert res.counts["unit_functions"] >= 2


def test_allowlist_entry_without_reason_is_an_error(tmp_path):
    bad = tmp_path / "allow.toml"
    bad.write_text('[[allow]]\nchecker = "hostsync"\n'
                   'site = "engine.py:Engine._step:int"\n')
    res = _fixture("hostsync_allowed", allow=bad)
    assert not res.ok()
    assert res.allow_errors


# ------------------------------------------------------- seeded violation


def test_seeded_violation_is_caught():
    """Break the tree in-memory: a method touching a guarded attr with
    no lock held must turn the clean run red."""
    source = (DEFAULT_SRC / "engine" / "engine.py").read_text()
    # keep the '# runs-on: engine-loop' comment glued to _run_loop —
    # inserting between them would re-target the annotation
    needle = "\n    # runs-on: engine-loop\n    def _run_loop"
    assert needle in source
    evil = ("\n    def _evil(self):\n"
            "        return len(self._pending)\n" + needle)
    res = run(override={"engine/engine.py":
                        source.replace(needle, evil, 1)})
    assert not res.ok()
    assert any(f.checker == "locks" and f.qualname.endswith("._evil")
               for f in res.findings)


def test_seeded_use_after_donate_is_caught():
    """Read the donated pool between the step and the rebind — the
    exact hazard adopt_pages exists to prevent."""
    source = (DEFAULT_SRC / "engine" / "engine.py").read_text()
    needle = "        kv.adopt_pages(new_k, new_v)"
    assert needle in source
    evil = ("        checksum = kv.k.sum()\n" + needle)
    res = run(override={"engine/engine.py":
                        source.replace(needle, evil, 1)})
    assert any(f.checker == "devmem" and f.symbol == "use-after-donate"
               and f.qualname.endswith("._decode_paged")
               for f in res.findings)


def test_seeded_d2h_in_hot_path_is_caught():
    """An un-annotated np.asarray on the donated pool's device arrays
    must be flagged as an implicit transfer."""
    source = (DEFAULT_SRC / "engine" / "kvcache.py").read_text()
    needle = "    def adopt_pages(self, k, v) -> None:"
    assert needle in source
    evil = needle + "\n        shadow = np.asarray(self.k)"
    res = run(override={"engine/kvcache.py":
                        source.replace(needle, evil, 1)})
    assert any(f.checker == "devmem" and f.symbol == "d2h"
               and f.qualname.endswith(".adopt_pages")
               for f in res.findings)


def test_seeded_vmem_overflow_is_caught():
    """Shrinking a kernel's declared budget below its static footprint
    must turn the run red."""
    rel = "kernels/flash_attention/kernel.py"
    source = (DEFAULT_SRC / rel).read_text()
    needle = "# vmem-budget: 2.0 MiB"
    assert needle in source
    res = run(override={rel: source.replace(
        needle, "# vmem-budget: 0.5 MiB", 1)})
    assert any(f.checker == "kernel" and f.symbol == "vmem-budget"
               and "exceeds the declared budget" in f.message
               for f in res.findings)


def test_seeded_unit_mismatch_is_caught():
    """Pricing KV migration at host_bw instead of link_bw is the §11.6
    channel confusion the units checker exists for."""
    rel = "core/cost_model.py"
    source = (DEFAULT_SRC / rel).read_text()
    needle = "tokens * prof.kv_bytes_per_token / self.hw.link_bw"
    assert needle in source
    res = run(override={rel: source.replace(
        needle,
        "tokens * prof.kv_bytes_per_token / self.hw.host_bw", 1)})
    assert any(f.checker == "units"
               and "priced over the @host path" in f.message
               for f in res.findings)


# ------------------------------------------------------------------- CLI


def test_cli_strict_exits_zero_on_tree():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.analysis", "--strict"],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_cli_exits_nonzero_on_violating_fixture():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.analysis", "--strict",
         "--root", str(FX / "locks_bad"),
         "--allowlist", str(NO_ALLOW)],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode != 0
    assert "bump_racy" in proc.stdout


def test_cli_only_restricts_checkers():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.analysis", "--strict",
         "--only", "units", "--json"],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    import json
    payload = json.loads(proc.stdout)
    assert payload["ok"]
    # locks/hostsync allowlist entries are waived under --only units
    assert payload["unused_allowlist"] == []


def test_cli_sarif_output(tmp_path):
    sarif_path = tmp_path / "analysis.sarif"
    proc = subprocess.run(
        [sys.executable, "-m", "tools.analysis",
         "--root", str(FX / "units_bad"),
         "--allowlist", str(NO_ALLOW),
         "--sarif", str(sarif_path)],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode != 0          # fixture violates on purpose
    import json
    doc = json.loads(sarif_path.read_text())
    assert doc["version"] == "2.1.0"
    run_ = doc["runs"][0]
    assert run_["tool"]["driver"]["name"] == "tools.analysis"
    results = run_["results"]
    assert results, "violating fixture must produce SARIF results"
    for r in results:
        loc = r["locations"][0]["physicalLocation"]
        assert loc["region"]["startLine"] >= 1
        assert loc["artifactLocation"]["uri"].endswith("cost.py")
    assert any(r["ruleId"].startswith("units/") for r in results)


def test_cli_sarif_on_clean_tree_is_empty(tmp_path):
    sarif_path = tmp_path / "clean.sarif"
    proc = subprocess.run(
        [sys.executable, "-m", "tools.analysis", "--strict",
         "--sarif", str(sarif_path)],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    import json
    doc = json.loads(sarif_path.read_text())
    assert doc["runs"][0]["results"] == []


# ------------------------------------------------- debugsync runtime layer


def test_named_lock_disabled_is_plain_lock(monkeypatch):
    monkeypatch.delenv("REPRO_DEBUG_SYNC", raising=False)
    lk = debugsync.named_lock("TestPlain.lk")
    assert isinstance(lk, type(threading.Lock()))
    assert isinstance(debugsync.named_condition("TestPlain.cv"),
                      threading.Condition)


def test_lock_order_inversion_raises(monkeypatch):
    monkeypatch.setenv("REPRO_DEBUG_SYNC", "1")
    a = debugsync.named_lock("TestInv.a")
    b = debugsync.named_lock("TestInv.b")
    with a:
        with b:
            pass
    with pytest.raises(debugsync.LockOrderError):
        with b:
            with a:
                pass
    assert debugsync.registry().held() == []


def test_reentrant_same_name_is_not_an_inversion(monkeypatch):
    monkeypatch.setenv("REPRO_DEBUG_SYNC", "1")
    cv = debugsync.named_condition("TestReent.cv")
    with cv:
        with cv:
            pass
    assert debugsync.registry().held() == []


def test_condition_wait_repushes_held_stack(monkeypatch):
    monkeypatch.setenv("REPRO_DEBUG_SYNC", "1")
    cv = debugsync.named_condition("TestWait.cv")
    ready, held_after_wait = [], []

    def waiter():
        with cv:
            while not ready:
                cv.wait(timeout=5)
            held_after_wait.extend(debugsync.registry().held())

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    with cv:
        ready.append(1)
        cv.notify()
    t.join(timeout=5)
    assert not t.is_alive()
    assert "TestWait.cv" in held_after_wait
    assert debugsync.registry().held() == []


# -------------------------------------------- regressions (checker finds)


def test_batch_state_is_macro_done_locked_view():
    from repro.core import consolidate
    from repro.runtime.coordinator import BatchState
    from repro.workloads import build_workload

    g, bindings, _ = build_workload("w+", 4, seed=0)
    consolidate(g, bindings)
    st = BatchState(g, 4)
    assert not st.is_macro_done("draft")
    for q in range(4):
        st.set_result(q, "draft", f"r{q}")
    assert st.is_macro_done("draft")


def test_moe_router_combine_survives_strict_promotion():
    """devmem/CI-dtype-leg find: the router combine multiplied f32
    weights by a raw bool keep-mask — f32*bool has no promotion path
    under jax_numpy_dtype_promotion=strict.  Pin the .astype fix."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import MoEConfig
    from repro.engine.models.moe import moe_ffn, moe_init

    cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=8)
    p = moe_init(jax.random.PRNGKey(0), 16, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16), jnp.float32)
    with jax.numpy_dtype_promotion("strict"):
        out, aux = moe_ffn(x, p, cfg)
    assert out.shape == x.shape
    assert out.dtype == jnp.float32


def test_kvcache_hbm_bytes_uses_pool_dtype():
    """devmem find: hbm_bytes() defaulted to 2 bytes/elem (bf16) while
    the pool allocates float32 — a silent 2x undercount."""
    import jax.numpy as jnp
    from repro.engine.kvcache import PagedKVCache

    kv = PagedKVCache(num_layers=1, num_pages=2, page_size=4,
                      kv_heads=2, head_dim=8)      # default f32 pool
    elems = 2 * 1 * 2 * 4 * 2 * 8
    assert kv.dtype == jnp.float32
    assert kv.hbm_bytes() == elems * 4             # pool's own width
    assert kv.hbm_bytes(dtype_bytes=2) == elems * 2  # explicit override
    bf16 = PagedKVCache(num_layers=1, num_pages=2, page_size=4,
                        kv_heads=2, head_dim=8, dtype=jnp.bfloat16)
    assert bf16.hbm_bytes() == elems * 2


def test_batched_sample_index_mask_is_int32_pinned():
    """devmem dtype find: the vocab mask built its arange without a
    dtype (platform-int width).  Pin the jnp.int32 fix end to end."""
    import jax
    import jax.numpy as jnp
    from repro.engine.engine import _batched_sample

    logits = jnp.zeros((2, 8), jnp.float32).at[:, 3].set(5.0)
    keys = jnp.zeros((2, 2), jnp.uint32)
    temps = jnp.zeros((2,), jnp.float32)
    with jax.numpy_dtype_promotion("strict"):
        toks, _ = _batched_sample(logits, keys, temps, vocab_size=6)
    assert toks.dtype == jnp.int32
    assert list(toks) == [3, 3]


def test_checkpoint_batch_size_mismatch_raises(tmp_path):
    from repro.runtime.checkpoint import (load_batch_state,
                                          save_batch_state)
    from repro.runtime.coordinator import BatchState
    from repro.workloads import build_workload

    g, _, _ = build_workload("w+", 4, seed=0)
    st = BatchState(g, 4)
    st.set_result(0, "draft", "r0")
    p = str(tmp_path / "ck.json")
    save_batch_state(st, p)
    with pytest.raises(ValueError, match="different batch size"):
        load_batch_state(BatchState(g, 3), p)
