"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref, lse_combine
from repro.kernels.common import NEG_INF
from repro.kernels.paged_decode_attention.ops import (
    fused_paged_decode_attention, paged_decode_attention)
from repro.kernels.paged_decode_attention.ref import (
    fused_paged_decode_attention_ref, paged_decode_attention_ref,
    scatter_append_ref)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rglru_scan.ops import linear_scan
from repro.kernels.rglru_scan.ref import linear_scan_ref
from repro.kernels.shared_prefix_attention.ops import shared_prefix_attention
from repro.kernels.shared_prefix_attention.ref import \
    shared_prefix_attention_ref

RNG = np.random.default_rng(7)


def _mk(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


@pytest.mark.parametrize("B,Sq,Skv,H,Hkv,Dh", [
    (1, 32, 32, 2, 2, 8),          # MHA
    (2, 64, 64, 4, 2, 16),         # GQA
    (2, 16, 64, 8, 1, 32),         # MQA, cross-length
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 24])
def test_flash_attention_sweep(B, Sq, Skv, H, Hkv, Dh, dtype, window):
    q, k, v = _mk((B, Sq, H, Dh), dtype), _mk((B, Skv, Hkv, Dh), dtype), \
        _mk((B, Skv, Hkv, Dh), dtype)
    qp = jnp.broadcast_to(jnp.arange(Skv - Sq, Skv, dtype=jnp.int32),
                          (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32), (B, Skv))
    out = flash_attention(q, k, v, q_positions=qp, kv_positions=kp,
                          causal=True, window=window, block_q=16,
                          block_kv=16, interpret=True)
    ref = flash_attention_ref(q, k, v, q_positions=qp, kv_positions=kp,
                              causal=True, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,T,H,Hkv,Dh", [
    (2, 64, 4, 2, 16), (1, 32, 8, 8, 8), (3, 48, 6, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, T, H, Hkv, Dh, dtype):
    q = _mk((B, H, Dh), dtype)
    k, v = _mk((B, T, Hkv, Dh), dtype), _mk((B, T, Hkv, Dh), dtype)
    qp = jnp.asarray(RNG.integers(T // 2, T, size=(B,)), jnp.int32)
    kp = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    kp = jnp.where(kp <= qp[:, None], kp, -1)
    out = decode_attention(q, k, v, q_positions=qp, kv_positions=kp,
                           block_t=16, interpret=True)
    ref = decode_attention_ref(q, k, v, q_positions=qp, kv_positions=kp)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_decode_attention_lse_split_invariance():
    """Splitting the KV into chunks + lse_combine == one full pass."""
    B, T, H, Hkv, Dh = 2, 64, 4, 2, 16
    q = _mk((B, H, Dh))
    k, v = _mk((B, T, Hkv, Dh)), _mk((B, T, Hkv, Dh))
    qp = jnp.full((B,), T - 1, jnp.int32)
    kp = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    full = decode_attention_ref(q, k, v, q_positions=qp, kv_positions=kp)
    parts = []
    for lo in range(0, T, 16):
        parts.append(decode_attention_ref(
            q, k[:, lo:lo+16], v[:, lo:lo+16], q_positions=qp,
            kv_positions=kp[:, lo:lo+16], return_lse=True))
    merged = lse_combine(parts)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,NP,ps,H,Hkv,Dh", [
    (2, 4, 8, 4, 2, 16),           # GQA
    (1, 3, 16, 8, 8, 8),           # MHA
    (3, 5, 8, 6, 1, 32),           # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_sweep(B, NP, ps, H, Hkv, Dh, dtype):
    """Paged kernel vs gather-dense oracle over a shuffled page pool,
    with NON-ALIGNED lengths (every row ends mid-page) and one padded
    (length = -1) row when the batch allows."""
    P = 2 * B * NP                                 # pool larger than used
    q = _mk((B, H, Dh), dtype)
    k_pages, v_pages = _mk((P, ps, Hkv, Dh), dtype), _mk((P, ps, Hkv, Dh), dtype)
    pt = jnp.asarray(RNG.permutation(P)[:B * NP].reshape(B, NP), jnp.int32)
    # partial-page boundaries: length % ps != 0 for every live row
    lens = np.asarray(RNG.integers((NP - 1) * ps, NP * ps - 1, size=(B,)),
                      np.int32)
    lens = np.where(lens % ps == 0, lens + 1, lens)
    if B > 1:
        lens[-1] = -1                              # padded batch row
    lens = jnp.asarray(lens)
    out = paged_decode_attention(q, k_pages, v_pages, pt, lens,
                                 interpret=True)
    ref = paged_decode_attention_ref(q, k_pages, v_pages, pt, lens)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_paged_decode_attention_aliased_pages_and_lse():
    """Two rows may alias the SAME physical pages (prefix sharing); the
    kernel reads them independently, and its (m, l) outputs combine like
    the contiguous decode kernel's."""
    B, NP, ps, H, Hkv, Dh = 2, 3, 8, 4, 2, 16
    P = 8
    q = _mk((B, H, Dh))
    k_pages, v_pages = _mk((P, ps, Hkv, Dh)), _mk((P, ps, Hkv, Dh))
    pt = jnp.asarray([[0, 1, 2], [0, 1, 4]], jnp.int32)  # shared prefix pages
    lens = jnp.asarray([ps * 2 + 3, ps * 2 + 5], jnp.int32)
    out, m, l = paged_decode_attention(q, k_pages, v_pages, pt, lens,
                                       return_lse=True, interpret=True)
    ref, mr, lr = paged_decode_attention_ref(q, k_pages, v_pages, pt, lens,
                                             return_lse=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr), atol=2e-5,
                               rtol=2e-5)
    np.testing.assert_allclose(np.asarray(l), np.asarray(lr), atol=2e-5,
                               rtol=2e-5)


def _paged_case(B=3, NP=5, ps=8, H=4, Hkv=2, Dh=16, seed=11):
    """Shuffled pool with non-aligned lengths, one aliased-prefix pair,
    and one padded row — the hostile layout every variant must handle."""
    rng = np.random.default_rng(seed)
    P = 2 * B * NP
    q = jnp.asarray(rng.normal(size=(B, H, Dh)), jnp.float32)
    k_pages = jnp.asarray(rng.normal(size=(P, ps, Hkv, Dh)), jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(P, ps, Hkv, Dh)), jnp.float32)
    pt = np.asarray(rng.permutation(P)[:B * NP].reshape(B, NP), np.int32)
    pt[1, :2] = pt[0, :2]                  # rows 0/1 alias prefix pages
    # length >= 2 pages keeps every row's WRITE page out of the aliased
    # prefix — prepare_append guarantees write pages are refcount-1
    # private, and the fused kernel relies on it
    lens = np.asarray(rng.integers(2 * ps + 1, NP * ps - 2, size=(B,)),
                      np.int32)
    lens = np.where(lens % ps == 0, lens + 1, lens)   # all end mid-page
    lens[-1] = -1                                     # padded batch row
    return q, k_pages, v_pages, jnp.asarray(pt), jnp.asarray(lens)


@pytest.mark.parametrize("ppb", [2, 3, 4])             # 3, 4 don't divide 5
@pytest.mark.parametrize("layout", ["bh", "hb"])
def test_paged_blocked_parity_sweep(ppb, layout):
    """Multi-page double-buffered blocks are BITWISE identical to the
    single-page variant for every (pages_per_block, grid layout) — the
    masked tail pages of a partial block are exact no-ops in the
    online-softmax recurrence."""
    q, kp, vp, pt, lens = _paged_case()
    base = paged_decode_attention(q, kp, vp, pt, lens, variant="single",
                                  interpret=True)
    out = paged_decode_attention(q, kp, vp, pt, lens, variant="blocked",
                                 pages_per_block=ppb, grid_layout=layout,
                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))
    ref = paged_decode_attention_ref(q, kp, vp, pt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("ppb", [2, 3])
@pytest.mark.parametrize("layout", ["bh", "hb"])
def test_fused_paged_parity_sweep(ppb, layout):
    """Fused append+attend == scatter-then-attend, bitwise: the same
    outputs AND the same pool contents afterwards.  Covers aliased READ
    pages (write pages are private per the prepare_append contract),
    partial-page append offsets, and a padded row that must write
    nothing."""
    q, kp, vp, pt, lens = _paged_case(seed=13)
    rng = np.random.default_rng(99)
    B, Hkv, Dh = q.shape[0], kp.shape[2], q.shape[2]
    k_new = jnp.asarray(rng.normal(size=(B, Hkv, Dh)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(B, Hkv, Dh)), jnp.float32)
    out, k_out, v_out = fused_paged_decode_attention(
        q, kp, vp, pt, lens, k_new, v_new, pages_per_block=ppb,
        grid_layout=layout, interpret=True)
    # scatter-then-attend arm (the path the fused kernel replaces)
    ks, vs = scatter_append_ref(kp, vp, pt, lens, k_new, v_new)
    base = paged_decode_attention(q, ks, vs, pt, lens, variant="blocked",
                                  pages_per_block=ppb, grid_layout=layout,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))
    np.testing.assert_array_equal(np.asarray(k_out), np.asarray(ks))
    np.testing.assert_array_equal(np.asarray(v_out), np.asarray(vs))
    ref = fused_paged_decode_attention_ref(q, kp, vp, pt, lens, k_new,
                                           v_new)[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_fused_padding_row_writes_nothing():
    """A padded (length = -1) row's k_new/v_new must NOT reach the pool —
    the fused write is gated, not clamped, so no page is corrupted."""
    q, kp, vp, pt, lens = _paged_case(seed=17)
    B, Hkv, Dh = q.shape[0], kp.shape[2], q.shape[2]
    k_new = jnp.full((B, Hkv, Dh), 1e6, jnp.float32)   # poison marker
    v_new = jnp.full((B, Hkv, Dh), -1e6, jnp.float32)
    _, k_out, v_out = fused_paged_decode_attention(
        q, kp, vp, pt, lens, k_new, v_new, pages_per_block=2,
        interpret=True)
    ks, vs = scatter_append_ref(kp, vp, pt, lens, k_new, v_new)
    np.testing.assert_array_equal(np.asarray(k_out), np.asarray(ks))
    # the padded row is lens[-1]: none of ITS pages may contain poison
    for pg in np.asarray(pt)[-1]:
        assert not np.any(np.asarray(k_out)[pg] == 1e6)
        assert not np.any(np.asarray(v_out)[pg] == -1e6)


@pytest.mark.parametrize("variant", ["single", "blocked"])
def test_paged_padding_row_ml_pin(variant):
    """Fully-masked padding rows pin (m, l) = (NEG_INF, 0) and a zero
    output EXACTLY — the lse_combine identity element, so split-phase
    merges ignore them (no NaN, no spurious weight)."""
    q, kp, vp, pt, lens = _paged_case()
    out, m, l = paged_decode_attention(
        q, kp, vp, pt, lens, variant=variant, pages_per_block=2,
        return_lse=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(out)[-1], 0.0)
    np.testing.assert_array_equal(np.asarray(m)[-1], np.float32(NEG_INF))
    np.testing.assert_array_equal(np.asarray(l)[-1], 0.0)


@pytest.mark.parametrize("P,Ts", [(32, 16), (64, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_shared_prefix_attention_sweep(P, Ts, dtype):
    B, H, Hkv, Dh = 2, 4, 2, 16
    q = _mk((B, H, Dh), dtype)
    pk, pv = _mk((P, Hkv, Dh), dtype), _mk((P, Hkv, Dh), dtype)
    sk, sv = _mk((B, Ts, Hkv, Dh), dtype), _mk((B, Ts, Hkv, Dh), dtype)
    qp = jnp.full((B,), P + Ts - 1, jnp.int32)
    sp = P + jnp.broadcast_to(jnp.arange(Ts, dtype=jnp.int32), (B, Ts))
    out = shared_prefix_attention(q, pk, pv, sk, sv, q_positions=qp,
                                  suffix_positions=sp, block_p=16,
                                  block_t=8, interpret=True)
    ref = shared_prefix_attention_ref(q, pk, pv, sk, sv, q_positions=qp,
                                      suffix_positions=sp)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("B,S,D", [(2, 32, 64), (4, 64, 32), (1, 16, 128)])
def test_rglru_scan_sweep(B, S, D):
    a = jnp.asarray(RNG.uniform(0.7, 0.999, size=(B, S, D)), jnp.float32)
    b = _mk((B, S, D))
    out = linear_scan(a, b, block_b=2, block_s=8, block_d=32, interpret=True)
    ref = linear_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
