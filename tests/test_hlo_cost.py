"""HLO cost analyzer: while-trip accounting, dot flops, collectives."""
import jax
import jax.numpy as jnp
from jax import lax

from repro.launch.hlo_cost import HloAnalyzer, analyze_hlo


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_body_trip_multiplication():
    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)

    def scan10(a):
        def body(c, _):
            return c @ c, None
        out, _ = lax.scan(body, a, None, length=10)
        return out

    def one(a):
        return a @ a

    r10 = analyze_hlo(_compile_text(scan10, x))
    r1 = analyze_hlo(_compile_text(one, x))
    assert abs(r10["flops"] / r1["flops"] - 10.0) < 0.01


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    r = analyze_hlo(_compile_text(lambda x, y: x @ y, a, b))
    assert r["flops"] == 2 * 128 * 256 * 64


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def nested(a):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            c, _ = lax.scan(inner, c, None, length=3)
            return c, None
        out, _ = lax.scan(outer, a, None, length=4)
        return out

    r = analyze_hlo(_compile_text(nested, x))
    one = analyze_hlo(_compile_text(lambda a: a @ a, x))
    assert abs(r["flops"] / one["flops"] - 12.0) < 0.05


def test_wrapped_line_merging():
    text = """HloModule m
ENTRY %main (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  %w = (s32[], f32[4]{0},
    f32[8]{0}) tuple(%p)
}
"""
    an = HloAnalyzer(text)
    assert an.entry == "main"
    kinds = [o.kind for o in an.comps["main"]]
    assert "tuple" in kinds        # the wrapped tuple line parsed as one op


def test_score_class_separation():
    # rank-4 f32 with a score-dim trailing axis goes to vmem_class
    def attn_like(q, k):
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k)   # (B,H,Sq,Skv)
        return s.sum()
    q = jax.ShapeDtypeStruct((2, 64, 4, 32), jnp.float32)
    k = jax.ShapeDtypeStruct((2, 2048, 4, 32), jnp.float32)
    r = analyze_hlo(_compile_text(attn_like, q, k), score_dims={2048})
    assert r["vmem_class_bytes"] > 0
    assert r["bytes"] < r["bytes_xla_path"]
