"""Cross-worker KV-cache migration (paper §5: Processor "KV-cache
sharing and migration") + regression pins for the admission/coalescing/
reporting bugfixes that rode along.

Fast suite: every test here runs in the per-push CI matrix (no ``slow``
marker), so keep instances tiny — n<=3 queries, decode_cap<=3.
"""
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import CostModel, HARDWARE, PAPER_MODELS, consolidate
from repro.core.graphspec import GraphSpec, NodeSpec, NodeType
from repro.core.plan import Epoch, ExecutionPlan
from repro.core.state import WorkerContext
from repro.engine.engine import InferenceEngine
from repro.engine.kvcache import PagedKVCache


# ---------------------------------------------------------------------------
# cache level: export/import round trip + page accounting
# ---------------------------------------------------------------------------

def test_kvcache_export_import_round_trip_and_conservation():
    """export_sequence/import_sequence move bit-identical KV and leave
    refcounts / the free list conserved after both sides release."""
    src = PagedKVCache(num_layers=2, num_pages=16, page_size=4,
                       kv_heads=2, head_dim=8)
    rng = np.random.default_rng(0)
    k = rng.standard_normal((2, 10, 2, 8)).astype(np.float32)
    v = rng.standard_normal((2, 10, 2, 8)).astype(np.float32)
    seq = src.add_sequence(k, v)

    ke, ve = src.export_sequence(seq, 7)
    assert ke.shape == (2, 7, 2, 8)
    np.testing.assert_array_equal(ke, k[:, :7])
    # exported block is a COPY: mutating the source pages can't corrupt it
    src.k = src.k.at[:, src.page_table(seq)[0]].add(1.0)
    np.testing.assert_array_equal(ke, k[:, :7])

    dst = PagedKVCache(num_layers=2, num_pages=16, page_size=4,
                       kv_heads=2, head_dim=8)
    free_before = len(dst.free_pages)
    seq2 = dst.import_sequence(ke, ve)
    assert dst.sequences[seq2].length == 7
    assert len(dst.free_pages) == free_before - 2        # ceil(7/4) pages
    kg, vg = dst.gather(seq2)
    np.testing.assert_array_equal(kg, k[:, :7])
    np.testing.assert_array_equal(vg, v[:, :7])

    dst.free_sequence(seq2)
    src.free_sequence(seq)
    assert len(dst.free_pages) == dst.num_pages
    assert (dst.refcount == 0).all() and (src.refcount == 0).all()
    assert src.pages_in_use == 0 and len(src.free_pages) == src.num_pages


def test_kvcache_import_rejects_mismatched_layout():
    dst = PagedKVCache(num_layers=2, num_pages=8, page_size=4,
                       kv_heads=2, head_dim=8)
    bad = np.zeros((1, 4, 2, 8), np.float32)
    with pytest.raises(ValueError):
        dst.import_sequence(bad, bad)


# ---------------------------------------------------------------------------
# engine level: migrated prefixes are real warm donors, bitwise-safe
# ---------------------------------------------------------------------------

def test_engine_migration_round_trip_bitwise_identity():
    """A prefix exported from one engine and imported into a second is
    aliased by the second's admission path, and temperature-0 outputs
    are bitwise-identical to a never-migrated engine."""
    cfg = get_smoke("qwen3-1.7b")
    prompt = list(range(10, 24))
    src = InferenceEngine(cfg, seed=0, page_size=8)
    try:
        out_src = src.generate([prompt], max_new_tokens=6)[0]
        depth = src.probe_prefix(prompt)
        assert depth == len(prompt)
        tokens, k, v = src.export_prefix(prompt)
        assert list(tokens) == prompt[:depth]
        # out-pages are credited by the migrator on CONFIRMED import
        # only, never at export time
        assert src.stats.pages_migrated_out == 0
    finally:
        src.shutdown()

    dst = InferenceEngine(cfg, seed=0, page_size=8)
    try:
        pages = dst.import_prefix(tokens, k, v, migrate_seconds=0.5)
        assert pages == 2
        assert dst.stats.pages_migrated_in == 2
        assert dst.stats.migrate_seconds == 0.5
        # re-import of a resident prefix is a no-op
        assert dst.import_prefix(tokens, k, v) == 0
        out_dst = dst.generate([prompt], max_new_tokens=6)[0]
        assert out_dst == out_src
        assert dst.stats.prefix_hits == 1                # aliased the import
        assert dst.stats.prefill_tokens_saved == len(prompt) - 1
        # page conservation after releasing the warm set
        dst.release_warm()
        assert dst.kv.pages_in_use == 0 and not dst.kv.sequences
    finally:
        dst.shutdown()

    ref = InferenceEngine(cfg, seed=0, page_size=8)
    try:
        assert ref.generate([prompt], max_new_tokens=6)[0] == out_src
    finally:
        ref.shutdown()


def test_engine_import_skips_when_pool_has_no_headroom():
    """import_prefix is best-effort: an import that cannot fit returns 0
    WITHOUT evicting the destination's own warm sequences first (an
    infeasible import must not wipe warm locality just to fail)."""
    cfg = get_smoke("qwen3-1.7b")
    eng = InferenceEngine(cfg, seed=0, page_size=8, num_pages=4)
    try:
        eng.generate([list(range(10, 18))], max_new_tokens=2)  # warm donor
        warm_before = dict(eng._warm)
        assert warm_before
        layers, heads, dh = eng.model.paged_kv_layout()
        k = np.zeros((layers, 40, heads, dh), np.float32)   # 5 pages > pool
        assert eng.import_prefix(list(range(100, 140)), k, k) == 0
        assert eng.stats.pages_migrated_in == 0
        assert dict(eng._warm) == warm_before               # nothing evicted
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------------
# planner level: t_migrate prices remote warm lineage honestly
# ---------------------------------------------------------------------------

def _two_node_graph():
    nodes = [NodeSpec("a", NodeType.LLM, model="qwen3-14b",
                      est_prompt_tokens=256),
             NodeSpec("b", NodeType.LLM, model="qwen3-14b",
                      est_prompt_tokens=256)]
    return GraphSpec("mig", nodes, [("a", "b")])


def test_cost_model_migration_credit_and_decision():
    g = _two_node_graph()
    cm = CostModel(g, HARDWARE["h200"], PAPER_MODELS,
                   avg_context_tokens=128.0)
    v = g.nodes["b"]
    cold = WorkerContext(model="qwen3-14b")
    warm_peer = WorkerContext(model="qwen3-14b", warm=("a",))

    # no peers: full prefill, no migration term
    eff0, mig0 = cm.prefill_plan(v, cold, ["a"])
    assert eff0 == 256.0 and mig0 == 0.0
    # warm peer: tokens credited, transfer term charged
    eff1, mig1 = cm.prefill_plan(v, cold, ["a"], peer_ctxs=(warm_peer,))
    assert eff1 == 256.0 - 128.0
    assert mig1 == cm.t_migrate(v, 128.0) > 0.0
    # local warm beats remote warm: same credit, no transfer cost
    eff2, mig2 = cm.prefill_plan(v, warm_peer, ["a"],
                                 peer_ctxs=(warm_peer,))
    assert eff2 == eff1 and mig2 == 0.0
    # t_node with a warm peer is cheaper than fully cold but dearer
    # than locally warm — the placement-move price is honest
    t_cold = cm.t_node("b", cold, frozenset({"a"}))[0]
    t_peer = cm.t_node("b", cold, frozenset({"a"}), peer_ctxs=(warm_peer,))[0]
    t_local = cm.t_node("b", warm_peer, frozenset({"a"}))[0]
    assert t_local < t_peer < t_cold
    assert cm.migration_wins(v, 128.0)


def test_cost_model_migration_loses_on_slow_link():
    """When the modeled link is slower than re-prefilling, the credit is
    withheld (migrate-vs-recompute)."""
    from dataclasses import replace
    g = _two_node_graph()
    hw = replace(HARDWARE["h200"], link_bw=1e3)          # ~dial-up NVLink
    cm = CostModel(g, hw, PAPER_MODELS, avg_context_tokens=128.0)
    v = g.nodes["b"]
    warm_peer = WorkerContext(model="qwen3-14b", warm=("a",))
    eff, mig = cm.prefill_plan(v, WorkerContext(model="qwen3-14b"),
                               ["a"], peer_ctxs=(warm_peer,))
    assert eff == 256.0 and mig == 0.0
    assert not cm.migration_wins(v, 128.0)


def test_cost_model_no_migration_credit_for_recurrent_state():
    from repro.core import LLMProfile
    nodes = [NodeSpec("a", NodeType.LLM, model="rec", est_prompt_tokens=100),
             NodeSpec("b", NodeType.LLM, model="rec", est_prompt_tokens=100)]
    g = GraphSpec("rec", nodes, [("a", "b")])
    rec = LLMProfile.from_params("rec", 1e9, 8, 4, 64,
                                 supports_partial_prefix=False)
    cm = CostModel(g, HARDWARE["h200"], {"rec": rec},
                   avg_context_tokens=128.0)
    warm_peer = WorkerContext(model="rec", warm=("a",))
    eff, mig = cm.prefill_plan(g.nodes["b"], WorkerContext(model="rec"),
                               ["a"], peer_ctxs=(warm_peer,))
    assert eff == 100.0 and mig == 0.0                   # state rows don't ship


# ---------------------------------------------------------------------------
# runtime level: forced replan across workers, warm hosts — the e2e A/B
# ---------------------------------------------------------------------------

def test_forced_replan_migrates_and_saves_prefill_bitwise_identical():
    """Acceptance e2e: a forced replan moving nodes across warm hosts
    reports pages_migrated > 0 and strictly more prefill_tokens_saved
    than the migration-off control, with identical temp-0 outputs."""
    from benchmarks.common import run_migration_ab
    rep_on, rep_off, warm = run_migration_ab(n=2)
    assert rep_on.extra["plan_splices"] == 1
    assert rep_on.extra["replans"] == 1
    assert rep_on.extra["pages_migrated_in"] > 0
    # in/out counters track confirmed transfers symmetrically
    assert (rep_on.extra["pages_migrated_out"]
            == rep_on.extra["pages_migrated_in"])
    assert rep_on.migration_summary()["pages_migrated"] > 0
    assert rep_on.migration_summary()["nodes_moved"] > 0
    assert rep_on.migration_summary()["migrate_seconds"] > 0
    assert (rep_on.extra["prefill_tokens_saved"]
            > rep_off.extra["prefill_tokens_saved"])
    assert rep_off.extra.get("pages_migrated_in", 0) == 0
    # semantics preserved: migration on / off / never-replanned agree
    assert (rep_on.results() == rep_off.results()
            == warm.results())


def test_migrator_assignment_diff_only_reports_real_moves():
    from repro.runtime.coordinator import PlanBoard
    from repro.runtime.migrate import KVMigrator
    from repro.workloads import build_workload
    g, bindings, _ = build_workload("w+", 2, seed=0)
    dag = g.llm_dag()
    plan = ExecutionPlan([Epoch([["draft", "refine", "final"]], [0])])
    board = PlanBoard(plan, dag, 2)
    assert board.try_claim(0) == "draft"                 # claimed: stays put
    tail = ExecutionPlan([Epoch([["draft"]], [1]),       # claimed -> ignored
                          Epoch([["refine"]], [1]),      # real move 0 -> 1
                          Epoch([["final"]], [0])])      # stays on 0
    mig = KVMigrator(g, hosts=[None, None])
    assert mig.assignment_diff(board, tail) == [("refine", 0, 1)]


# ---------------------------------------------------------------------------
# bugfix pins
# ---------------------------------------------------------------------------

def test_same_wave_duplicates_coalesce_at_admission():
    """Seed bug: _coalesce only scanned _active, so a leader that
    retired within the admission pass (small max_new) let its same-wave
    duplicate prefill again.  Duplicates still in _pending now attach as
    followers at admission."""
    cfg = get_smoke("qwen3-1.7b")
    p = list(range(30, 40))
    eng = InferenceEngine(cfg, seed=0)
    try:
        o1, o2 = eng.generate([p, p], max_new_tokens=1)
        assert o1 == o2
        assert eng.stats.coalesced_requests == 1
        assert eng.stats.prefill_tokens == len(p)        # exactly one prefill
        assert eng.stats.prefix_hits == 0                # not via page alias
    finally:
        eng.shutdown()


def test_impossible_page_demand_fails_fast_with_diagnostic():
    """Seed bug: a request that can NEVER fit (demand > whole pool)
    deferred forever behind in-flight work and surfaced as a bare 600s
    TimeoutError.  It must fail immediately with a diagnosis, without
    disturbing the running batch."""
    cfg = get_smoke("qwen3-1.7b")
    eng = InferenceEngine(cfg, seed=0, page_size=8, num_pages=16,
                          max_seq_len=4096)
    try:
        ok = eng.submit(list(range(10, 18)), max_new_tokens=24)
        huge = eng.submit(list(range(600)), max_new_tokens=8)  # >16 pages
        with pytest.raises(MemoryError, match="never|cannot"):
            huge.result(timeout=60)
        assert ok.result(timeout=120)                    # batch survived
    finally:
        eng.shutdown()


def test_peak_batch_reported_per_run():
    """Seed bug: report.extra['peak_batch'] read the engines' all-time
    gauge, so a small micro-batch on persistent hosts re-reported an
    earlier run's peak.  The watermark now resets at run start."""
    from benchmarks.common import make_real_processor
    from repro.runtime.executors import EngineHost
    proc, g, cons, bindings, plan = make_real_processor("w+", 3, 2, 2)
    hosts = [EngineHost(proc.model_configs, seed=proc.seed)
             for _ in range(2)]
    try:
        r1 = proc.run(cons, plan, hosts=hosts)
        cons1 = consolidate(g, bindings[:1])
        r2 = proc.run(cons1, plan, hosts=hosts)
        assert r1.extra["peak_batch"] >= 2
        assert r2.extra["peak_batch"] == 1               # not run 1's gauge
    finally:
        for h in hosts:
            h.shutdown()
