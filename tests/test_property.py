"""Property-based tests (hypothesis) on system invariants."""
import string

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.coalesce import CoalesceTable, canonical_signature
from repro.core.cost_model import CostModel, HARDWARE, PAPER_MODELS
from repro.core.graphspec import GraphSpec, NodeSpec, NodeType
from repro.core.solver import EpochDPSolver, SolverConfig
from repro.engine.prefix_tree import RadixPrefixTree, batch_shared_prefix
from repro.kernels.decode_attention.ref import decode_attention_ref, lse_combine

# ---------------------------------------------------------------------------
# coalescing
# ---------------------------------------------------------------------------

sql_text = st.text(alphabet=string.ascii_letters + " ='0123456789_",
                   min_size=1, max_size=60)


@given(sql_text, st.integers(0, 8), st.integers(0, 8))
def test_signature_whitespace_invariance(body, pre, post):
    a = canonical_signature("sql", body)
    b = canonical_signature("sql", " " * pre + " ".join(body.split())
                            + " " * post + ";")
    assert a == b


@given(st.lists(st.sampled_from(["q1", "q2", "q3", "q4"]),
                min_size=1, max_size=30))
def test_coalesce_physical_equals_unique(reqs):
    tab = CoalesceTable()
    sigs = set()
    for i, r in enumerate(reqs):
        sig, _, _ = tab.register("sql", f"SELECT {r}", (i, "n"))
        sigs.add(sig)
    assert tab.physical_executions == len(sigs)
    assert tab.logical_requests == len(reqs)
    # completing every physical task fans out to every logical requester
    total = sum(len(tab.complete(s, "r")) for s in list(tab.pending))
    assert total == len(reqs)


# ---------------------------------------------------------------------------
# prefix tree
# ---------------------------------------------------------------------------

tokens = st.lists(st.integers(0, 50), min_size=0, max_size=24)


@given(tokens, tokens)
def test_radix_match_is_common_prefix(a, b):
    tree = RadixPrefixTree()
    tree.insert(a)
    n, _ = tree.match(b)
    brute = 0
    for x, y in zip(a, b):
        if x != y:
            break
        brute += 1
    assert n == brute


@given(st.lists(tokens, min_size=1, max_size=8))
def test_batch_shared_prefix_is_prefix_of_all(prompts):
    p = batch_shared_prefix(prompts)
    for x in prompts:
        assert list(x[:len(p)]) == p


# ---------------------------------------------------------------------------
# LSE combine == monolithic softmax for ANY split
# ---------------------------------------------------------------------------

@given(st.integers(1, 4), st.lists(st.integers(1, 3), min_size=1, max_size=4),
       st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_lse_split_invariance(B, chunk_sizes, seed):
    rng = np.random.default_rng(seed)
    Hkv, G, Dh = 2, 2, 8
    T = 8 * sum(chunk_sizes)
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, Dh)), jnp.float32)
    qp = jnp.full((B,), T - 1, jnp.int32)
    kp = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    full = decode_attention_ref(q, k, v, q_positions=qp, kv_positions=kp)
    parts, lo = [], 0
    for c in chunk_sizes:
        hi = lo + 8 * c
        parts.append(decode_attention_ref(
            q, k[:, lo:hi], v[:, lo:hi], q_positions=qp,
            kv_positions=kp[:, lo:hi], return_lse=True))
        lo = hi
    np.testing.assert_allclose(np.asarray(lse_combine(parts)),
                               np.asarray(full), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# solver on random DAGs: plans are always valid & complete
# ---------------------------------------------------------------------------

@st.composite
def random_dag(draw):
    n = draw(st.integers(2, 6))
    models = ["qwen3-14b", "qwen3-32b", "gpt-oss-20b"]
    nodes = [NodeSpec(id=f"n{i}", type=NodeType.LLM,
                      model=models[draw(st.integers(0, 2))],
                      prompt=f"p{i}", est_prompt_tokens=64,
                      max_new_tokens=16)
             for i in range(n)]
    edges = []
    for j in range(1, n):
        for i in range(j):
            if draw(st.booleans()):
                edges.append((f"n{i}", f"n{j}"))
    return GraphSpec("rand", nodes, edges)


@given(random_dag(), st.integers(1, 3))
@settings(max_examples=20, deadline=None)
def test_dp_solver_valid_on_random_dags(graph, workers):
    dag = graph.llm_dag()
    cm = CostModel(graph, HARDWARE["h200"], PAPER_MODELS,
                   batch_sizes={v: 2 for v in graph.nodes})
    plan = EpochDPSolver(dag, cm, SolverConfig(num_workers=workers)).solve()
    plan.validate(dag)                               # precedence + coverage
    seen = [v for e in plan.epochs for c in e.components for v in c]
    assert sorted(seen) == sorted(dag.node_ids)      # exactly once


# ---------------------------------------------------------------------------
# cost model monotonicity
# ---------------------------------------------------------------------------

@given(st.integers(1, 512), st.integers(1, 512))
@settings(max_examples=30, deadline=None)
def test_infer_cost_monotone_in_batch(b1, b2):
    from repro.core.state import WorkerContext
    spec = NodeSpec(id="x", type=NodeType.LLM, model="qwen3-14b",
                    prompt="p", est_prompt_tokens=128, max_new_tokens=32)
    g = GraphSpec("g", [spec], [])
    cm = CostModel(g, HARDWARE["h200"], PAPER_MODELS)
    ctx = WorkerContext(model="qwen3-14b")
    cm.batch_sizes["x"] = min(b1, b2)
    lo = cm.t_infer(spec, ctx, [])
    cm.batch_sizes["x"] = max(b1, b2)
    hi = cm.t_infer(spec, ctx, [])
    assert lo <= hi + 1e-12
