"""Distribution layer: sharding-policy divisibility (pure logic) and
shard_map collectives (subprocess with 8 host devices)."""
import os
import subprocess
import sys
from types import SimpleNamespace

import jax
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.distribution.sharding import ShardingPolicy, _spec_for_leaf
from repro.engine.models import build_model


def _fake_mesh(shape_dict):
    return SimpleNamespace(shape=shape_dict)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_policy_specs_always_divisible(arch):
    """Every generated PartitionSpec divides its tensor dim — jax would
    reject NamedShardings otherwise (llama3.2-3b's 24 heads etc.)."""
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    mesh = _fake_mesh({"pod": 2, "data": 16, "model": 16})
    pol = ShardingPolicy(fsdp_axes=("pod", "data"),
                         batch_axes=("pod", "data"))

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, f"{prefix}/{k}")
            return
        if isinstance(tree, (list, tuple)):
            for i, v in enumerate(tree):
                walk(v, f"{prefix}/{i}")
            return
        spec = _spec_for_leaf(prefix, tree.shape, mesh, pol)
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert tree.shape[dim] % n == 0, (prefix, tree.shape, spec)

    walk(shapes)


def test_big_weights_are_sharded():
    cfg = get_config("qwen3-8b")
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    mesh = _fake_mesh({"data": 16, "model": 16})
    pol = ShardingPolicy()
    spec = _spec_for_leaf("/embed", shapes["embed"].shape, mesh, pol)
    assert any(e is not None for e in spec)
    blocks_wq = shapes["blocks"]["attn"]["wq"]
    spec = _spec_for_leaf("/blocks/attn/wq", blocks_wq.shape, mesh, pol)
    assert spec[0] is None                      # stacked layer dim untouched
    assert any(e is not None for e in spec[1:])


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.distribution.collectives import (sharded_decode_attention,
                                            compressed_psum_grads)
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.training.grad_compress import init_error_state

from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
B, H, Hkv, Dh, T = 2, 4, 2, 16, 32
q = jnp.asarray(rng.normal(size=(B, H, Dh)), jnp.float32)
k = jnp.asarray(rng.normal(size=(B, T, Hkv, Dh)), jnp.float32)
v = jnp.asarray(rng.normal(size=(B, T, Hkv, Dh)), jnp.float32)
qp = jnp.array([25, 31], jnp.int32)
kp = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
kp = jnp.where(kp <= qp[:, None], kp, -1)
out = sharded_decode_attention(q, k, v, qp, kp, mesh=mesh)
ref = decode_attention_ref(q, k, v, q_positions=qp, kv_positions=kp)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           atol=2e-5, rtol=2e-5)

mesh2 = compat_make_mesh((2, 2, 2), ("pod", "data", "model"))
g = {"w": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)}
mean_g, _ = compressed_psum_grads(g, init_error_state(g), mesh=mesh2)
rel = float(jnp.abs(mean_g["w"] - g["w"]).max() / jnp.abs(g["w"]).max())
assert rel < 0.02, rel
print("SUBPROC_OK")
"""


@pytest.mark.multidevice
def test_shard_map_collectives_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC],
                       capture_output=True, text=True, timeout=300,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       env=env)
    assert "SUBPROC_OK" in r.stdout, r.stderr[-2000:]
