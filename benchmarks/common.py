"""Shared benchmark plumbing: cost models, plans, baseline system models.

Baseline systems are modeled per §6.1:
* vllm-serial   — query-by-query: N × single-query makespan;
* opwise        — stage-synchronous executor (OpWiseSimulator);
* langgraph     — decoupled orchestration: engine-level batching still
                  applies (requests submitted together) but NO workflow-
                  level coalescing and topology-blind RR placement;
* agentscope    — actor isolation: like langgraph but placement is
                  random (actors don't coordinate workers);
* parrot        — prefix/semantic-aware serving: engine batching +
                  locality-greedy (HEFT-style) placement, but no tool
                  coalescing and no CPU-GPU co-scheduling.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.core import (CostModel, EpochDPSolver, HARDWARE, PAPER_MODELS,
                        SolverConfig, consolidate, consolidate_multi,
                        heft_plan, random_plan, round_robin_plan)
from repro.core.consolidate import ConsolidatedGraph, MultiConsolidatedGraph
from repro.core.graphspec import GraphSpec
from repro.runtime import OpWiseSimulator, SimulatedProcessor
from repro.workloads import MIXED_PARTS, build_mixed_workload, build_workload


def setup(workload: str, n: int, seed: int = 0
          ) -> Tuple[GraphSpec, ConsolidatedGraph]:
    g, bindings, _ = build_workload(workload, n, seed=seed)
    return g, consolidate(g, bindings), bindings


def setup_multi(n: int, seed: int = 0, parts=MIXED_PARTS
                ) -> Tuple[GraphSpec, MultiConsolidatedGraph, list, str]:
    """(merged graph, multi-cons, per-template batches, database) for a
    mixed multi-template batch."""
    batches, db = build_mixed_workload(n, seed=seed, parts=parts)
    mc = consolidate_multi(batches)
    return mc.template, mc, batches, db


def make_cm(g: GraphSpec, cons: ConsolidatedGraph, *, logical_tools=False,
            hardware="h200", **kw) -> CostModel:
    batch = {}
    for nid in g.nodes:
        m = cons.macro(nid)
        # tools price their PHYSICAL count — multi-template mega-DAGs
        # drop signatures another template's node already owns
        batch[nid] = (m.n_logical if (g.nodes[nid].is_llm() or logical_tools)
                      else len(cons.physical_signatures(nid)))
    return CostModel(g, HARDWARE[hardware], PAPER_MODELS,
                     batch_sizes=batch, warm_aliases=cons.warm_aliases(),
                     **kw)


def halo_plan(g, cons, workers=3, **cm_kw):
    cm = make_cm(g, cons, **cm_kw)
    return EpochDPSolver(g.llm_dag(), cm,
                         SolverConfig(num_workers=workers)).solve()


def run_halo(g, cons, workers=3, hardware="h200", processor_batch=256,
             plan=None):
    plan = plan or halo_plan(g, cons, workers, hardware=hardware)
    sim = SimulatedProcessor(g, make_cm(g, cons, hardware=hardware), workers,
                             processor_batch=processor_batch)
    return sim.run(cons, plan)


def run_opwise(g, cons, workers=3, hardware="h200", processor_batch=256):
    return OpWiseSimulator(g, make_cm(g, cons, hardware=hardware), workers,
                           processor_batch=processor_batch).run(cons)


def run_langgraph(g, cons, workers=3, hardware="h200"):
    cm = make_cm(g, cons, logical_tools=True, hardware=hardware)
    plan = round_robin_plan(g.llm_dag(), cm, workers)
    sim = SimulatedProcessor(g, cm, workers, coalescing=False,
                             kv_migration=False)
    rep = sim.run(cons, plan)
    rep.name = "langgraph"
    return rep


def run_agentscope(g, cons, workers=3, hardware="h200", seed=1):
    cm = make_cm(g, cons, logical_tools=True, hardware=hardware)
    plan = random_plan(g.llm_dag(), cm, workers, seed=seed)
    sim = SimulatedProcessor(g, cm, workers, coalescing=False,
                             kv_migration=False)
    rep = sim.run(cons, plan)
    rep.name = "agentscope"
    return rep


def run_parrot(g, cons, workers=3, hardware="h200"):
    cm = make_cm(g, cons, logical_tools=True, hardware=hardware)
    plan = heft_plan(g.llm_dag(), cm, workers)
    sim = SimulatedProcessor(g, cm, workers, coalescing=False,
                             kv_migration=False)
    rep = sim.run(cons, plan)
    rep.name = "parrot"
    return rep


def run_vllm_serial(g, cons_full, workers=3, hardware="h200"):
    """Query-by-query: the whole DAG for one query completes before the
    next starts (engine sees batch=1 everywhere)."""
    g1, cons1, _ = setup_from(g, cons_full, 1)
    cm = make_cm(g1, cons1, logical_tools=True, hardware=hardware)
    plan = round_robin_plan(g1.llm_dag(), cm, workers)
    rep1 = SimulatedProcessor(g1, cm, workers, coalescing=False,
                              kv_migration=False).run(cons1, plan)
    n = cons_full.n_queries
    rep1.makespan *= n
    rep1.num_queries = n
    rep1.name = "vllm-serial"
    return rep1


def setup_from(g, cons, n):
    sub = ConsolidatedGraph(g, cons.bindings[:n])
    return g, sub, cons.bindings[:n]


BASELINES = {
    "halo": run_halo,
    "opwise": run_opwise,
    "langgraph": run_langgraph,
    "agentscope": run_agentscope,
    "parrot": run_parrot,
}


# ---------------------------------------------------------------------------
# real-engine mode (tiny smoke models behind the continuous-batching engine)
# ---------------------------------------------------------------------------

def smoke_models_for(g: GraphSpec):
    """Map every model the graph names onto a tiny smoke config so the
    real continuous-batching engines can run it on CPU."""
    from repro.configs import get_smoke
    names = {g.nodes[n].model for n in g.llm_nodes()}
    return {m: get_smoke("qwen3-1.7b").replace(name=m) for m in names}


def make_real_processor(workload="w+", n=6, workers=2, decode_cap=4,
                        seed=0, latency_scale=0.0, **proc_kw):
    """(processor, graph, cons, bindings, plan) for real-engine runs.

    ``proc_kw`` holds further ProcessorConfig fields (``pipelining``,
    ``engine_kwargs``, ...)."""
    from repro.runtime import ProcessorConfig, RealProcessor
    from repro.workloads.datagen import build_database
    from repro.workloads.tools import ToolRuntime
    g, bindings, dbname = build_workload(workload, n, seed=seed)
    cons = consolidate(g, bindings)
    plan = halo_plan(g, cons, workers)
    proc = RealProcessor(
        g, smoke_models_for(g),
        ToolRuntime(build_database(dbname), latency_scale=latency_scale),
        config=ProcessorConfig(num_workers=workers, decode_cap=decode_cap,
                               seed=seed, **proc_kw))
    return proc, g, cons, bindings, plan


def swapped_tail(plan, g, workers: int):
    """Forced-replan tail moving EVERY LLM node to the next worker
    (singleton topo-order epochs) — the migration A/B stimulus shared by
    benchmarks and tests."""
    from repro.core.plan import Epoch, ExecutionPlan
    amap = plan.assignment_map()
    llm = set(g.llm_dag().node_ids)
    topo = [v for v in g.topo_order() if v in llm]
    return ExecutionPlan(
        [Epoch([[n]], [(amap[n] + 1) % workers]) for n in topo],
        scheduler_name="forced-swap")


def run_migration_ab(workload="w+", n=4, workers=2, decode_cap=3):
    """Warm persistent hosts, then re-run under a forced splice that
    moves every node across workers — once with cross-worker KV
    migration, once without.  Returns (rep_on, rep_off, warm_rep);
    the shared harness behind the migration benchmark rows AND the
    acceptance test."""
    from repro.runtime import OnlineOptimizer
    from repro.runtime.executors import EngineHost
    reps = {}
    for migration in (True, False):
        proc, g, cons, _, plan = make_real_processor(
            workload, n, workers, decode_cap, kv_migration=migration)
        hosts = [EngineHost(proc.model_configs, seed=proc.seed)
                 for _ in range(workers)]
        try:
            warm = proc.run(cons, plan, hosts=hosts)
            # drift threshold pinned high: ONLY the queued forced splice
            # may fire, so the A/B stimulus is deterministic (CPU smoke
            # latencies sit far off the roofline and would otherwise
            # drift-replan on their own, timing-dependently)
            opt = OnlineOptimizer(make_cm(g, cons), drift_threshold=1e9)
            opt.queue_splice(swapped_tail(plan, g, workers))
            reps[migration] = proc.run(cons, plan, hosts=hosts,
                                       optimizer=opt)
        finally:
            for h in hosts:
                h.shutdown()
    return reps[True], reps[False], warm


def run_paged_ab(workload="wt", n=4, workers=2, decode_cap=4):
    """Warm persistent hosts, then measure the SAME run with the
    device-resident paged decode path vs the dense-view reference path.
    Returns (rep_paged, rep_dense); the paged row shows
    ``view_rebuilds == 0`` and an order-of-magnitude drop in
    ``h2d_bytes + d2h_bytes`` (per-step KV traffic is O(batch) ints,
    not O(batch x seq_len) KV), with bitwise-identical temp-0 outputs.
    KV migration is off in both arms so the counters isolate the decode
    path (migration staging is legitimate h2d/d2h on both)."""
    from repro.runtime.executors import EngineHost
    reps = {}
    for paged in (True, False):
        proc, g, cons, _, plan = make_real_processor(
            workload, n, workers, decode_cap, kv_migration=False,
            engine_kwargs={"paged_decode": paged})
        hosts = [EngineHost(proc.model_configs, seed=proc.seed,
                            engine_kwargs=proc.engine_kwargs)
                 for _ in range(workers)]
        try:
            proc.run(cons, plan, hosts=hosts)     # warm pages + JIT caches
            reps[paged] = proc.run(cons, plan, hosts=hosts)
        finally:
            for h in hosts:
                h.shutdown()
    return reps[True], reps[False]


def run_kernel_ab(workload="wt", n=4, workers=2, decode_cap=4):
    """Warm persistent hosts, then measure the SAME paged run with the
    autotuned fused multi-page kernel vs the single-page baseline.
    Returns (rep_fused, rep_single, interpret).

    Both arms run the Pallas paged-decode path (``paged_decode`` on);
    only ``kernel_variant`` differs, so the delta isolates the kernel:
    multi-page double-buffered KV blocks plus the fused append
    (eliminating the separate scatter dispatch per decode step).
    Temp-0 outputs are bitwise identical across arms — masked pages are
    exact no-ops in the online-softmax recurrence.  On CPU hosts the
    kernels run under the Pallas interpreter (``interpret=True``), where
    timings are meaningless; callers gate throughput claims on the
    returned flag."""
    import jax
    from repro.kernels import env_interpret
    from repro.runtime.executors import EngineHost
    interp = env_interpret(False) or jax.default_backend() != "tpu"
    impl = "pallas_interpret" if interp else "pallas"
    reps = {}
    for variant in ("fused", "single"):
        proc, g, cons, _, plan = make_real_processor(
            workload, n, workers, decode_cap, kv_migration=False,
            engine_kwargs={"paged_decode": True,
                           "kernel_variant": variant})
        proc.model_configs = {m: c.replace(attention_impl=impl)
                              for m, c in proc.model_configs.items()}
        hosts = [EngineHost(proc.model_configs, seed=proc.seed,
                            engine_kwargs=proc.engine_kwargs)
                 for _ in range(workers)]
        try:
            proc.run(cons, plan, hosts=hosts)     # warm pages + JIT caches
            reps[variant] = proc.run(cons, plan, hosts=hosts)
        finally:
            for h in hosts:
                h.shutdown()
    return reps["fused"], reps["single"], interp


def interleaved_epochs(plan, mc: MultiConsolidatedGraph) -> int:
    """Epochs whose macro-nodes come from >= 2 templates — the shared
    decode batches only a mega-DAG plan can form."""
    n = 0
    for e in plan.epochs:
        tmpls = {mc.template_of[v] for comp in e.components for v in comp}
        if len(tmpls) >= 2:
            n += 1
    return n


def run_multi_sim_ab(n: int = 384, workers: int = 3, seed: int = 0,
                     parts=MIXED_PARTS):
    """Simulated consolidated-multi vs per-template-serial A/B.

    The multi arm plans ONE mega-DAG over the mixed batch (epoch packing
    may interleave templates; cross-template signatures dedup); the
    serial arm consolidates and runs each template's slice on its own,
    one after another.  Returns (rep_multi, serial_makespan, plan, mc).
    """
    g, mc, batches, _ = setup_multi(n, seed=seed, parts=parts)
    plan = halo_plan(g, mc, workers)
    rep = SimulatedProcessor(g, make_cm(g, mc), workers).run(mc, plan)
    serial = 0.0
    for tg, tb in batches:
        cons = consolidate(tg, tb)
        p = halo_plan(tg, cons, workers)
        serial += SimulatedProcessor(
            tg, make_cm(tg, cons), workers).run(cons, p).makespan
    return rep, serial, plan, mc


def make_real_multi_processor(n=6, workers=2, decode_cap=3, seed=0,
                              parts=MIXED_PARTS, **proc_kw):
    """(processor, merged graph, multi-cons, batches, plan, db) for a
    real-engine mixed-batch run."""
    from repro.runtime import ProcessorConfig, RealProcessor
    from repro.workloads.datagen import build_database
    from repro.workloads.tools import ToolRuntime
    g, mc, batches, db = setup_multi(n, seed=seed, parts=parts)
    plan = halo_plan(g, mc, workers)
    proc = RealProcessor(
        g, smoke_models_for(g),
        ToolRuntime(build_database(db), latency_scale=0.0),
        config=ProcessorConfig(num_workers=workers, decode_cap=decode_cap,
                               seed=seed, **proc_kw))
    return proc, g, mc, batches, plan, db


def run_real_multi_ab(n: int = 6, workers: int = 2, decode_cap: int = 3,
                      seed: int = 0, parts=MIXED_PARTS):
    """REAL-engine consolidated-multi vs per-template-serial A/B.

    Returns (rep_multi, serial_reports, serial_seconds, mc, plan).  The
    serial arm runs each template's slice as its own batch, one after
    another.  BOTH arms run on warm persistent hosts (one throwaway run
    first, like the other A/B harnesses) so the measurement is
    steady-state serving, not JIT compilation, and both arms are timed
    the SAME way (their reports' makespans; serial sums them) so fixed
    setup cost can't bias the comparison; outputs are
    bitwise-comparable to the multi arm's at temperature 0.
    """
    from repro.runtime import ProcessorConfig, RealProcessor
    from repro.runtime.executors import EngineHost
    from repro.workloads.datagen import build_database
    from repro.workloads.tools import ToolRuntime
    proc, g, mc, batches, plan, db = make_real_multi_processor(
        n, workers, decode_cap, seed, parts)
    hosts = [EngineHost(proc.model_configs, seed=proc.seed)
             for _ in range(workers)]
    try:
        proc.run(mc, plan, hosts=hosts)              # warm (JIT + pages)
        rep_multi = proc.run(mc, plan, hosts=hosts)
    finally:
        for h in hosts:
            h.shutdown()
    serial_reports = []
    serial_seconds = 0.0
    for tg, tb in batches:
        cons = consolidate(tg, tb)
        p = halo_plan(tg, cons, workers)
        pr = RealProcessor(
            tg, smoke_models_for(tg),
            ToolRuntime(build_database(db), latency_scale=0.0),
            config=ProcessorConfig(num_workers=workers,
                                   decode_cap=decode_cap, seed=seed))
        shosts = [EngineHost(pr.model_configs, seed=pr.seed)
                  for _ in range(workers)]
        try:
            pr.run(cons, p, hosts=shosts)            # warm
            rep = pr.run(cons, p, hosts=shosts)
            serial_reports.append(rep)
            serial_seconds += rep.makespan
        finally:
            for h in shosts:
                h.shutdown()
    return rep_multi, serial_reports, serial_seconds, mc, plan


def engine_stat_cols(rep) -> Dict[str, float]:
    """The continuous-batching engine counters a RunReport carries."""
    x = rep.extra
    return {
        "prefill_tokens_saved": x.get("prefill_tokens_saved", 0),
        "kv_pages_shared": x.get("pages_shared", 0),
        "kv_tokens_reused": x.get("tokens_reused", 0),
        "admission_waves": x.get("admission_waves", 0),
        "peak_batch": x.get("peak_batch", 0),
        "coalesced_requests": x.get("coalesced_requests", 0),
        "cpu_gpu_overlap_s": x.get("cpu_gpu_overlap_s", 0.0),
        "replans": x.get("replans", 0),
        "pages_migrated": x.get("pages_migrated_in", 0),
        "migrate_s": x.get("migrate_seconds", 0.0),
        "h2d_bytes": x.get("h2d_bytes", 0),
        "d2h_bytes": x.get("d2h_bytes", 0),
        "view_rebuilds": x.get("view_rebuilds", 0),
    }
