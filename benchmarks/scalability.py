"""Fig. 8 — scalability: batch-size scaling and worker elasticity (W3)."""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import run_halo, run_opwise, setup


def run(workload: str = "w3") -> List[Dict]:
    rows = []
    for n in (256, 512, 1024, 2048):
        g, cons, _ = setup(workload, n)
        halo = run_halo(g, cons, 3)
        opw = run_opwise(g, cons, 3)
        rows.append({"axis": "batch", "value": n,
                     "halo_s": round(halo.makespan, 1),
                     "opwise_s": round(opw.makespan, 1),
                     "halo_qps": round(halo.throughput_qps(), 3)})
    # worker elasticity on a workload WITH branch parallelism (W1 diamond;
    # a pure chain like W3 cannot use >1 worker at macro granularity)
    g, cons, _ = setup("w1", 256)
    for wk in (1, 2, 3):
        halo = run_halo(g, cons, wk)
        opw = run_opwise(g, cons, wk)
        rows.append({"axis": "workers", "value": wk,
                     "halo_s": round(halo.makespan, 1),
                     "opwise_s": round(opw.makespan, 1),
                     "halo_qps": round(halo.throughput_qps(), 3)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
