"""Fig. 8 — scalability: batch-size scaling and worker elasticity (W3),
plus the data-scale enumerated batch (DESIGN.md §12.1) and the durable
job-store recovery arms (DESIGN.md §12.2)."""
from __future__ import annotations

import os
import tempfile
from typing import Dict, List

from benchmarks.common import (make_real_processor, run_halo, run_opwise,
                               setup)


def run(workload: str = "w3") -> List[Dict]:
    rows = []
    for n in (256, 512, 1024, 2048):
        g, cons, _ = setup(workload, n)
        halo = run_halo(g, cons, 3)
        opw = run_opwise(g, cons, 3)
        rows.append({"axis": "batch", "value": n,
                     "halo_s": round(halo.makespan, 1),
                     "opwise_s": round(opw.makespan, 1),
                     "halo_qps": round(halo.throughput_qps(), 3)})
    # worker elasticity on a workload WITH branch parallelism (W1 diamond;
    # a pure chain like W3 cannot use >1 worker at macro granularity)
    g, cons, _ = setup("w1", 256)
    for wk in (1, 2, 3):
        halo = run_halo(g, cons, wk)
        opw = run_opwise(g, cons, wk)
        rows.append({"axis": "workers", "value": wk,
                     "halo_s": round(halo.makespan, 1),
                     "opwise_s": round(opw.makespan, 1),
                     "halo_qps": round(halo.throughput_qps(), 3)})
    return rows


def scale_rows(limit: int = 2048) -> List[Dict]:
    """Data-scale smoke: >= 2000 ENUMERATED queries (one per finewiki
    pages row, DESIGN.md §12.1) consolidated and run through the
    simulator path whole — pins that the mega-DAG machinery holds at
    the paper's thousands-of-queries scale."""
    from repro.core.consolidate import consolidate
    from repro.workloads import build_enumerated_workload
    g, bindings, _, _ = build_enumerated_workload("ws", limit=limit)
    cons = consolidate(g, bindings)
    halo = run_halo(g, cons, 3)
    opw = run_opwise(g, cons, 3)
    uniq = sum(cons.macros[nid].n_unique for nid in g.nodes)
    return [{"system": "halo-sim-enumerated", "workload": "ws",
             "n_queries": limit, "unique_signatures": uniq,
             "makespan_s": round(halo.makespan, 1),
             "opwise_s": round(opw.makespan, 1),
             "halo_qps": round(halo.throughput_qps(), 3)}]


def recovery_rows() -> List[Dict]:
    """Durable job-store + fault-injection arms on the REAL engines
    (DESIGN.md §12.2/§12.3): a cold run journals, the resumed run must
    replay everything (zero re-executed signatures, zero decode), and a
    seeded chaos run (worker kill + tool faults) must still produce the
    cold run's outputs bitwise."""
    from repro.runtime import FaultPlan
    js = os.path.join(tempfile.mkdtemp(), "journal.jsonl")

    def go(**kw):
        proc, _, cons, _, plan = make_real_processor(
            "wt", n=6, workers=2, decode_cap=3, seed=0, **kw)
        return proc.run(cons, plan)

    cold = go(jobstore_path=js)
    warm = go(jobstore_path=js)
    chaos = go(faults=FaultPlan(seed=1, tool_fail_rate=0.5,
                                max_tool_failures=1, kill_worker={0: 1}),
               tool_retries=3)
    return [
        {"system": "halo-real-cold",
         "makespan_s": round(cold.makespan, 3),
         "jobstore": cold.extra["jobstore"]},
        {"system": "halo-real-resumed",
         "makespan_s": round(warm.makespan, 3),
         "jobstore": warm.extra["jobstore"],
         "decode_tokens": warm.extra["decode_tokens"],
         "outputs_match": warm.extra["results"] == cold.extra["results"]},
        {"system": "halo-real-chaos",
         "makespan_s": round(chaos.makespan, 3),
         "faults": chaos.extra["faults"],
         "tool_retries": chaos.extra["tool_retries"],
         "outputs_match": chaos.extra["results"] == cold.extra["results"]},
    ]


if __name__ == "__main__":
    for r in run() + scale_rows() + recovery_rows():
        print(r)
