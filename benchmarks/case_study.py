"""Fig. 11 — execution dynamics on W3: progress + GPU utilization trace,
cumulative GPU-seconds (the cloud-billing proxy)."""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import halo_plan, make_cm, setup
from repro.runtime import OpWiseSimulator, SimulatedProcessor


def run(workload: str = "w3", n_queries: int = 1024) -> List[Dict]:
    g, cons, _ = setup(workload, n_queries)
    plan = halo_plan(g, cons, 3)
    halo = SimulatedProcessor(g, make_cm(g, cons), 3).run(cons, plan)
    opw = OpWiseSimulator(g, make_cm(g, cons), 3).run(cons)

    rows = []
    for name, rep in (("halo", halo), ("opwise", opw)):
        trace = rep.utilization_trace(dt=max(rep.makespan / 40, 0.5))
        rows.append({
            "system": name,
            "makespan_s": round(rep.makespan, 1),
            "gpu_seconds": round(rep.gpu_seconds(), 1),
            "mean_utilization": round(
                sum(u for _, u in trace) / max(len(trace), 1), 3),
            "utilization_trace": [(round(t, 1), round(u, 2))
                                  for t, u in trace],
        })
    rows.append({
        "system": "ratio",
        "gpu_seconds_reduction": round(
            opw.gpu_seconds() / max(halo.gpu_seconds(), 1e-9), 2)})
    return rows


if __name__ == "__main__":
    for r in run(n_queries=64):
        print({k: v for k, v in r.items() if k != "utilization_trace"})
