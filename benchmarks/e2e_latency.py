"""Fig. 6 — end-to-end batch latency, W1–W6, Halo vs baselines.

``real_rows`` additionally executes the continuous-batching engine for
real (tiny smoke models on CPU) and reports its paged-KV serving
counters — pages shared, tokens reused, admission waves — next to the
makespan.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import (BASELINES, engine_stat_cols,
                               make_real_processor, run_vllm_serial, setup)

WORKLOADS = ("w1", "w2", "w3", "w4", "w5", "w6")


def run(n_queries: int = 1024, workers: int = 3,
        include_real: bool = False) -> List[Dict]:
    rows = []
    for w in WORKLOADS:
        g, cons, _ = setup(w, n_queries)
        halo_t = None
        for name, fn in BASELINES.items():
            rep = fn(g, cons, workers)
            if name == "halo":
                halo_t = rep.makespan
            rows.append({"workload": w, "system": name,
                         "makespan_s": round(rep.makespan, 2),
                         "speedup_vs_halo": round(rep.makespan /
                                                  max(halo_t, 1e-9), 2)})
        serial = run_vllm_serial(g, cons, workers)
        rows.append({"workload": w, "system": "vllm-serial",
                     "makespan_s": round(serial.makespan, 2),
                     "speedup_vs_halo": round(serial.makespan /
                                              max(halo_t, 1e-9), 2)})
    if include_real:
        rows.extend(real_rows())
    return rows


def real_rows(n_queries: int = 6, workers: int = 2,
              decode_cap: int = 4) -> List[Dict]:
    """Real continuous-batching engines on the pure-LLM chain (w+)."""
    proc, _, cons, _, plan = make_real_processor(
        "w+", n_queries, workers, decode_cap)
    rep = proc.run(cons, plan)
    return [{"workload": "w+", "system": "halo-real",
             "makespan_s": round(rep.makespan, 2),
             **engine_stat_cols(rep)}]


if __name__ == "__main__":
    for r in run(256, include_real=True):
        print(r)
