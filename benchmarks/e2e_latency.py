"""Fig. 6 — end-to-end batch latency, W1–W6, Halo vs baselines.

``real_rows`` additionally executes the continuous-batching engine for
real (tiny smoke models on CPU) and reports its paged-KV serving
counters — pages shared, tokens reused, admission waves — next to the
makespan.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import (BASELINES, engine_stat_cols,
                               interleaved_epochs, make_real_processor,
                               run_multi_sim_ab, run_real_multi_ab,
                               run_vllm_serial, setup)

WORKLOADS = ("w1", "w2", "w3", "w4", "w5", "w6")


def run(n_queries: int = 1024, workers: int = 3,
        include_real: bool = False) -> List[Dict]:
    rows = []
    for w in WORKLOADS:
        g, cons, _ = setup(w, n_queries)
        halo_t = None
        for name, fn in BASELINES.items():
            rep = fn(g, cons, workers)
            if name == "halo":
                halo_t = rep.makespan
            rows.append({"workload": w, "system": name,
                         "makespan_s": round(rep.makespan, 2),
                         "speedup_vs_halo": round(rep.makespan /
                                                  max(halo_t, 1e-9), 2)})
        serial = run_vllm_serial(g, cons, workers)
        rows.append({"workload": w, "system": "vllm-serial",
                     "makespan_s": round(serial.makespan, 2),
                     "speedup_vs_halo": round(serial.makespan /
                                              max(halo_t, 1e-9), 2)})
    rows.extend(multi_rows(n_queries, workers))
    if include_real:
        rows.extend(real_rows())
    return rows


def multi_rows(n_queries: int = 384, workers: int = 3) -> List[Dict]:
    """Mixed wd+wt+w4 batch: ONE consolidated mega-DAG vs planning and
    running each template's slice separately (simulated backend).  The
    multi row reports the cross-template static dedup and how many plan
    epochs interleave macro-nodes from different templates — the wins
    per-template planning cannot see (docs/BENCHMARKS.md)."""
    rep, serial_s, plan, mc = run_multi_sim_ab(n_queries, workers)
    xt = mc.cross_template_summary()
    return [
        {"workload": "mixed", "system": "consolidated-multi",
         "makespan_s": round(rep.makespan, 2),
         "epochs": len(plan.epochs),
         "interleaved_epochs": interleaved_epochs(plan, mc),
         "cross_template_deduped": xt["cross_template_deduped"],
         # physical/unique across the mega-DAG's tool macros — NOT the
         # per-node unique/logical ratio ConsolidatedGraph
         # .static_dedup_ratio measures, hence the distinct name
         "xt_physical_ratio": round(
             xt["tool_physical"] / max(xt["tool_unique"], 1), 3)},
        {"workload": "mixed", "system": "per-template-serial",
         "makespan_s": round(serial_s, 2),
         "speedup_vs_multi": round(serial_s / max(rep.makespan, 1e-9), 2)},
    ]


def real_rows(n_queries: int = 6, workers: int = 2,
              decode_cap: int = 4) -> List[Dict]:
    """Real continuous-batching engines on the pure-LLM chain (w+)."""
    proc, _, cons, _, plan = make_real_processor(
        "w+", n_queries, workers, decode_cap)
    rep = proc.run(cons, plan)
    return [{"workload": "w+", "system": "halo-real",
             "makespan_s": round(rep.makespan, 2),
             **engine_stat_cols(rep)}] + pipelining_rows(
        n_queries, workers, max(decode_cap, 6)) + migration_rows(
        min(n_queries, 4), workers) + paged_rows(
        min(n_queries, 4), workers) + real_multi_rows(
        n_queries, workers)


def real_multi_rows(n_queries: int = 6, workers: int = 2,
                    decode_cap: int = 3) -> List[Dict]:
    """Mixed wd+wt+w4 batch through REAL engines: one mega-DAG run vs
    each template's slice run serially.  BOTH arms are measured warm
    (per-arm throwaway run first) and by their reports' makespans, so
    the comparison is steady-state serving throughput, not JIT/setup
    cost.  The multi row's makespan is <= the serial row's sum, it
    reports runtime cross-template tool merges (``xt_merged_requests``)
    next to the engine's page-sharing counters, and temp-0 outputs are
    bitwise-identical across the arms (pinned in
    tests/test_multi_template.py)."""
    rep, serial_reports, serial_s, mc, plan = run_real_multi_ab(
        n_queries, workers, decode_cap)
    xt = mc.cross_template_summary()
    return [
        {"workload": "mixed", "system": "consolidated-multi-real",
         "makespan_s": round(rep.makespan, 3),
         "epochs": len(plan.epochs),
         "interleaved_epochs": interleaved_epochs(plan, mc),
         "xt_deduped_static": xt["cross_template_deduped"],
         "xt_merged_requests": rep.coalesce_stats.get(
             "cross_template_merged_requests", 0),
         **engine_stat_cols(rep)},
        {"workload": "mixed", "system": "per-template-serial-real",
         "makespan_s": round(serial_s, 3),
         "speedup_vs_multi": round(serial_s / max(rep.makespan, 1e-9), 2)},
    ]


def pipelining_rows(n_queries: int = 6, workers: int = 2,
                    decode_cap: int = 6) -> List[Dict]:
    """WT tool-pipeline: per-request pipelining vs the macro barrier on
    WARM engines (steady-state serving; a first run pays JIT compile).
    The pipelined row shows ``cpu_gpu_overlap_s > 0`` — tool tasks of
    early-retiring queries running under the stragglers' decode."""
    from repro.runtime.executors import EngineHost
    rows = []
    for pipe, name in ((False, "halo-real-barrier"),
                       (True, "halo-real-pipelined")):
        proc, _, cons, _, plan = make_real_processor(
            "wt", n_queries, workers, decode_cap,
            latency_scale=1.0, pipelining=pipe)
        hosts = [EngineHost(proc.model_configs, seed=proc.seed)
                 for _ in range(workers)]
        try:
            proc.run(cons, plan, hosts=hosts)          # warm the engines
            rep = proc.run(cons, plan, hosts=hosts)
        finally:
            for h in hosts:
                h.shutdown()
        rows.append({"workload": "wt", "system": name,
                     "makespan_s": round(rep.makespan, 3),
                     **engine_stat_cols(rep)})
    return rows


def migration_rows(n_queries: int = 4, workers: int = 2,
                   decode_cap: int = 3) -> List[Dict]:
    """Cross-worker KV migration A/B on warm hosts: a forced replan
    moves every w+ node to the other worker, with migration on vs off.
    The on-row shows ``pages_migrated > 0`` and strictly more
    ``prefill_tokens_saved`` (the moved nodes' warm lineage follows them
    instead of stranding); outputs are identical either way."""
    from benchmarks.common import run_migration_ab
    rep_on, rep_off, _ = run_migration_ab(
        "w+", n_queries, workers, decode_cap)
    return [{"workload": "w+", "system": name,
             "makespan_s": round(rep.makespan, 3),
             **engine_stat_cols(rep)}
            for name, rep in (("halo-real-migrate", rep_on),
                              ("halo-real-no-migrate", rep_off))]


def paged_rows(n_queries: int = 4, workers: int = 2,
               decode_cap: int = 4) -> List[Dict]:
    """Device-resident paged decode vs the dense-view reference path on
    warm WT hosts.  The paged row shows ``view_rebuilds == 0`` and a
    >=10x drop in ``h2d_bytes + d2h_bytes`` (the host gather and the
    per-step KV tap sync are gone); outputs are identical either way."""
    from benchmarks.common import run_paged_ab
    rep_p, rep_d = run_paged_ab("wt", n_queries, workers, decode_cap)
    return [{"workload": "wt", "system": name,
             "makespan_s": round(rep.makespan, 3),
             **engine_stat_cols(rep)}
            for name, rep in (("halo-real-paged", rep_p),
                              ("halo-real-dense-view", rep_d))]


def kernel_rows(n_queries: int = 4, workers: int = 2,
                decode_cap: int = 4) -> List[Dict]:
    """Autotuned fused multi-page paged-decode kernel vs the single-page
    baseline on warm WT hosts (both arms paged + Pallas).  Rows carry
    tokens/s-per-device — the quantity the nightly gate and the >=1.3x
    fused-vs-single check track — plus ``outputs_match`` pinning the
    bitwise-identity contract.  On CPU hosts (``interpret: true``) the
    throughput numbers measure the Pallas interpreter and every timing
    gate skips them."""
    from benchmarks.common import run_kernel_ab
    rep_f, rep_s, interp = run_kernel_ab("wt", n_queries, workers,
                                         decode_cap)
    match = rep_f.extra.get("results") == rep_s.extra.get("results")
    rows = []
    for name, rep in (("halo-real-kernel-fused", rep_f),
                      ("halo-real-kernel-single", rep_s)):
        tps = rep.extra.get("decode_tokens", 0.0) / max(
            rep.makespan, 1e-9) / workers
        rows.append({"workload": "wt", "system": name,
                     "makespan_s": round(rep.makespan, 3),
                     "tokens_per_s_per_device": round(tps, 2),
                     "outputs_match": match, "interpret": interp,
                     **engine_stat_cols(rep)})
    return rows


if __name__ == "__main__":
    for r in run(256, include_real=True):
        print(r)
