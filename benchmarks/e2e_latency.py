"""Fig. 6 — end-to-end batch latency, W1–W6, Halo vs baselines."""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import (BASELINES, run_vllm_serial, setup)

WORKLOADS = ("w1", "w2", "w3", "w4", "w5", "w6")


def run(n_queries: int = 1024, workers: int = 3) -> List[Dict]:
    rows = []
    for w in WORKLOADS:
        g, cons, _ = setup(w, n_queries)
        halo_t = None
        for name, fn in BASELINES.items():
            rep = fn(g, cons, workers)
            if name == "halo":
                halo_t = rep.makespan
            rows.append({"workload": w, "system": name,
                         "makespan_s": round(rep.makespan, 2),
                         "speedup_vs_halo": round(rep.makespan /
                                                  max(halo_t, 1e-9), 2)})
        serial = run_vllm_serial(g, cons, workers)
        rows.append({"workload": w, "system": "vllm-serial",
                     "makespan_s": round(serial.makespan, 2),
                     "speedup_vs_halo": round(serial.makespan /
                                              max(halo_t, 1e-9), 2)})
    return rows


if __name__ == "__main__":
    for r in run(256):
        print(r)
