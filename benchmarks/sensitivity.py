"""Figs. 9–10 — sensitivity: model sizes, device generations, processor
batch size."""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import halo_plan, make_cm, setup
from repro.core.graphspec import GraphSpec
from repro.runtime import OpWiseSimulator, SimulatedProcessor

LIGHT = {"qwen3-14b": "qwen3-0.6b", "qwen3-32b": "qwen3-4b",
         "gpt-oss-20b": "qwen3-0.6b"}
HEAVY = {"qwen3-14b": "qwq-32b", "qwen3-32b": "qwen3-32b",
         "gpt-oss-20b": "deepseek-r1-distill-32b"}
DEVICES = {"D1-2xA100": ("a100", 2), "D2-2xH100": ("h100", 2),
           "D3-3xH200": ("h200", 3)}


def _remap_models(g: GraphSpec, mapping) -> GraphSpec:
    nodes = [n.with_(model=mapping.get(n.model, n.model)) if n.is_llm()
             else n for n in g.nodes.values()]
    return GraphSpec(g.name, nodes, g.edges)


def run(workload: str = "w3", n_queries: int = 256) -> List[Dict]:
    rows = []
    g0, cons, _ = setup(workload, n_queries)

    # ---- model size (Fig. 9 left) ------------------------------------
    for label, mapping in (("light", LIGHT), ("base", {}), ("heavy", HEAVY)):
        g = _remap_models(g0, mapping)
        plan = halo_plan(g, cons, 3)
        halo = SimulatedProcessor(g, make_cm(g, cons), 3).run(cons, plan)
        opw = OpWiseSimulator(g, make_cm(g, cons), 3).run(cons)
        rows.append({"axis": "model_size", "value": label,
                     "halo_s": round(halo.makespan, 1),
                     "opwise_s": round(opw.makespan, 1)})

    # ---- device generation (Fig. 9 right) ----------------------------
    for label, (hw, wk) in DEVICES.items():
        plan = halo_plan(g0, cons, wk, hardware=hw)
        halo = SimulatedProcessor(g0, make_cm(g0, cons, hardware=hw),
                                  wk).run(cons, plan)
        opw = OpWiseSimulator(g0, make_cm(g0, cons, hardware=hw),
                              wk).run(cons)
        rows.append({"axis": "device", "value": label,
                     "halo_s": round(halo.makespan, 1),
                     "opwise_s": round(opw.makespan, 1)})

    # ---- processor batch size (Fig. 10) -------------------------------
    for w in ("w3", "w4"):
        gg, cc, _ = setup(w, n_queries)
        plan = halo_plan(gg, cc, 3)
        for pb in (32, 64, 128, 256, 512, 1024):
            rep = SimulatedProcessor(gg, make_cm(gg, cc), 3,
                                     processor_batch=pb).run(cc, plan)
            rows.append({"axis": f"proc_batch[{w}]", "value": pb,
                         "halo_s": round(rep.makespan, 1)})
    return rows


if __name__ == "__main__":
    for r in run(n_queries=64):
        print(r)
