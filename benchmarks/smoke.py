"""Nightly benchmark smoke — tiny-N e2e latency + online serving.

    PYTHONPATH=src python -m benchmarks.smoke

Runs the simulated baselines at small N plus the REAL continuous-
batching engines (pipelined-vs-barrier WT rows, calibrated online
stream, streaming-session-vs-micro-batched A/B) and writes one
``BENCH_<section>.json`` per section into ``experiments/results/`` —
CI uploads them as artifacts so the perf trajectory is recorded run
over run.

The run FAILS (nonzero exit) when a guarded A/B inverts, instead of
silently uploading an artifact that contradicts the design claims:

* ``halo-real-pipelined`` must not lose to ``halo-real-barrier``
  (tool pipelining exists to hide CPU latency under decode);
* ``session-stream`` must not lose to ``micro-batched`` on makespan
  OR interactive p95 TTFT, and the arms' temp-0 outputs must match
  bitwise (DESIGN.md §10);
* ``halo-real-kernel-fused`` must not lose to ``-single`` on
  tokens/s-per-device, and the two kernel arms' temp-0 outputs must
  match bitwise — timing checks apply only to non-interpret rows
  (real hardware), the output check always.

On top of the A/B pairs, the kernel section self-compares run over
run: tokens/s-per-device from the PREVIOUS ``BENCH_kernels.json``
artifact (if present — CI restores it before overwriting) gates the
current run with the same 15% slack, so a kernel regression fails the
nightly even when both variants regress together.

``_AB_SLACK`` absorbs CI timing noise; a genuine inversion (like the
2026-08 artifact that showed pipelined at 4.51s vs barrier at 1.69s,
which never reproduced locally) is far outside it.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from benchmarks import e2e_latency, kernel_bench, online_serving, scalability

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "results")

_AB_SLACK = 1.15                 # winner may be up to 15% "slower" (noise)


def _row(rows: List[Dict], system: str) -> Dict:
    return next(r for r in rows if r.get("system") == system)


def static_analysis_rows() -> List[Dict]:
    """Counters from ``tools.analysis`` (DESIGN.md §11) as one row —
    the nightly artifact makes the host-sync budget and guarded-attr
    coverage a tracked series, not a one-time assertion."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from tools.analysis import run as analysis_run
    res = analysis_run()
    row = {"system": "tools-analysis", **res.counts,
           "strict_clean": res.ok(strict=True)}
    return [row]


def load_previous(name: str) -> List[Dict]:
    """Rows from the previous run's artifact, [] if absent/unreadable.
    Must be called BEFORE main() overwrites the file."""
    try:
        with open(os.path.join(OUT, f"{name}.json")) as f:
            rows = json.load(f)
        return rows if isinstance(rows, list) else []
    except (OSError, json.JSONDecodeError):
        return []


def check_kernel_regressions(rows: List[Dict],
                             prev: List[Dict]) -> List[str]:
    """Run-over-run tokens/s gate for the kernel section: each system's
    tokens/s-per-device must stay within ``_AB_SLACK`` of the previous
    artifact's.  Interpret-mode rows (Pallas interpreter on CPU CI) are
    never compared — their timings measure the interpreter."""
    bad = []
    prev_by_system = {r["system"]: r for r in prev
                      if not r.get("interpret")}
    for r in rows:
        if r.get("interpret"):
            continue
        p = prev_by_system.get(r.get("system"))
        if p is None:
            continue
        cur, old = (r.get("tokens_per_s_per_device"),
                    p.get("tokens_per_s_per_device"))
        if cur is None or old is None:
            continue
        if cur * _AB_SLACK < old:
            bad.append(f"KERNEL REGRESSION: {r['system']} "
                       f"tokens/s-per-device {cur} vs previous {old}")
    return bad


def check_inversions(sections: Dict[str, List[Dict]]) -> List[str]:
    """Guarded A/B pairs that must not invert.  Returns violations."""
    bad = []

    def must_beat(rows, winner, loser, metric):
        try:
            w, l = _row(rows, winner), _row(rows, loser)
        except StopIteration:
            return                           # section ran without the pair
        if w[metric] > l[metric] * _AB_SLACK:
            bad.append(f"A/B INVERSION: {winner} {metric}={w[metric]} vs "
                       f"{loser} {metric}={l[metric]}")

    rows = sections.get("BENCH_e2e_latency", [])
    must_beat(rows, "halo-real-pipelined", "halo-real-barrier",
              "makespan_s")
    rows = sections.get("BENCH_online_serving", [])
    must_beat(rows, "session-stream", "micro-batched", "makespan_s")
    must_beat(rows, "session-stream", "micro-batched",
              "interactive_p95_ttft_s")
    for r in rows:
        if r.get("outputs_match") is False:
            bad.append(f"OUTPUT MISMATCH: {r['system']} temp-0 outputs "
                       "differ between streaming and micro-batched arms")

    rows = sections.get("BENCH_scale", [])
    for r in rows:
        if r.get("outputs_match") is False:
            bad.append(f"OUTPUT MISMATCH: {r['system']} outputs differ "
                       "from the cold run's")
        if r.get("system") == "halo-real-resumed":
            re_exec = r.get("jobstore", {}).get("re_executed_signatures")
            if re_exec:
                bad.append(f"RESUME REGRESSION: resumed run re-executed "
                           f"{re_exec} journaled signatures (want 0)")
            if r.get("decode_tokens"):
                bad.append(f"RESUME REGRESSION: resumed run decoded "
                           f"{r['decode_tokens']} tokens (want 0)")

    rows = sections.get("BENCH_kernels", [])
    try:
        w = _row(rows, "halo-real-kernel-fused")
        l = _row(rows, "halo-real-kernel-single")
    except StopIteration:
        w = l = None
    if w is not None and not (w.get("interpret") or l.get("interpret")):
        # higher is better here, so the inversion test flips relative
        # to the makespan pairs above
        if w["tokens_per_s_per_device"] * _AB_SLACK < \
                l["tokens_per_s_per_device"]:
            bad.append(
                "A/B INVERSION: halo-real-kernel-fused tokens/s-per-device"
                f"={w['tokens_per_s_per_device']} vs halo-real-kernel-"
                f"single={l['tokens_per_s_per_device']}")
    for r in rows:
        if r.get("outputs_match") is False:
            bad.append(f"OUTPUT MISMATCH: {r['system']} temp-0 outputs "
                       "differ between fused and single kernel arms")
    return bad


def main() -> int:
    sections = {
        "BENCH_e2e_latency": lambda: e2e_latency.run(
            64, include_real=True),
        "BENCH_online_serving": lambda: (
            online_serving.run(32)
            + online_serving.real_stream_rows()
            + online_serving.session_stream_rows()),
        "BENCH_kernels": lambda: (
            kernel_bench.bench_rows(smoke=True)
            + e2e_latency.kernel_rows()),
        "BENCH_static_analysis": static_analysis_rows,
        "BENCH_scale": lambda: (scalability.scale_rows(2048)
                                + scalability.recovery_rows()),
    }
    os.makedirs(OUT, exist_ok=True)
    prev_kernels = load_previous("BENCH_kernels")
    results: Dict[str, List[Dict]] = {}
    for name, fn in sections.items():
        t0 = time.perf_counter()
        rows = fn()
        dt = time.perf_counter() - t0
        results[name] = rows
        path = os.path.join(OUT, f"{name}.json")
        with open(path, "w") as f:
            json.dump(rows, f, indent=1, default=str)
        print(f"{name}: {len(rows)} rows in {dt:.1f}s -> {path}")
        for r in rows:
            if str(r.get("system", "")).startswith(
                    ("halo-real", "session-stream", "micro-batched")):
                print("  ", r)
    violations = check_inversions(results)
    violations += check_kernel_regressions(
        results.get("BENCH_kernels", []), prev_kernels)
    for v in violations:
        print(v)
    return 1 if violations else 0


if __name__ == "__main__":
    raise SystemExit(main())
