"""Nightly benchmark smoke — tiny-N e2e latency + online serving.

    PYTHONPATH=src python -m benchmarks.smoke

Runs the simulated baselines at small N plus the REAL continuous-
batching engines (pipelined-vs-barrier WT rows, calibrated online
stream) and writes one ``BENCH_<section>.json`` per section into
``experiments/results/`` — CI uploads them as artifacts so the perf
trajectory is recorded run over run.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks import e2e_latency, online_serving

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "results")


def main() -> int:
    sections = {
        "BENCH_e2e_latency": lambda: e2e_latency.run(
            64, include_real=True),
        "BENCH_online_serving": lambda: (
            online_serving.run(32)
            + online_serving.real_stream_rows()),
    }
    os.makedirs(OUT, exist_ok=True)
    for name, fn in sections.items():
        t0 = time.perf_counter()
        rows = fn()
        dt = time.perf_counter() - t0
        path = os.path.join(OUT, f"{name}.json")
        with open(path, "w") as f:
            json.dump(rows, f, indent=1, default=str)
        print(f"{name}: {len(rows)} rows in {dt:.1f}s -> {path}")
        for r in rows:
            if str(r.get("system", "")).startswith("halo-real"):
                print("  ", r)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
