"""Roofline-driven paged-decode kernel autotune sweep.

    PYTHONPATH=src python -m benchmarks.kernel_bench [--smoke] [--json]
    PYTHONPATH=src python -m benchmarks.kernel_bench --persist [--out F]

Sweeps ``(variant, pages_per_block, grid_layout)`` — the single-page
baseline, multi-page double-buffered blocks, and the fused
append+attend variant — per pool shape, times each candidate warm, and
scores achieved HBM bandwidth against the ``launch/roofline.py`` peaks
(%-of-roofline).  ``--persist`` writes the per-shape winners into the
``autotune.json`` table that ``kernels/paged_decode_attention/ops.py``
consults at call time.

Persisting REFUSES to run when the sweep was measured in Pallas
interpret mode (``REPRO_PALLAS_INTERPRET=1``, or the automatic fallback
on a CPU-only host): interpret timings measure the interpreter, not the
TPU, and a table seeded from them would be meaningless.  Rows from an
interpret sweep are still exported (marked ``interpret: true``) so the
CI smoke exercises the full path; the nightly tokens/s gate in
``benchmarks/smoke.py`` likewise skips interpret rows.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import env_interpret
from repro.kernels.paged_decode_attention import ops as paged_ops
from repro.launch.roofline import paged_decode_cost, pct_of_roofline

# (name, B, H, Hkv, Dh, page_size, n_pages) — smoke first; the larger
# shapes mirror the serving configs and only run in a full sweep
SHAPES = [
    ("smoke-qwen3", 4, 4, 2, 16, 8, 8),
    ("decode-2k", 8, 32, 8, 128, 64, 32),
    ("decode-8k", 4, 32, 8, 128, 64, 128),
]

# the sweep grid; "single" ignores ppb/layout (one page per grid step)
CANDIDATES = [{"variant": "single", "pages_per_block": 1,
               "grid_layout": "bh"}] + [
    {"variant": variant, "pages_per_block": ppb, "grid_layout": layout}
    for variant in ("blocked", "fused")
    for ppb in (2, 4, 8)
    for layout in ("bh", "hb")
]


def interpret_mode() -> bool:
    """True when timings would measure the Pallas interpreter: the env
    override is set, or there is no TPU to compile for."""
    return env_interpret(False) or jax.default_backend() != "tpu"


def _make_inputs(B, H, Hkv, Dh, page_size, n_pages, seed=0):
    rng = np.random.default_rng(seed)
    P = 2 * B * n_pages
    q = jnp.asarray(rng.standard_normal((B, H, Dh)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((P, page_size, Hkv, Dh)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((P, page_size, Hkv, Dh)),
                     jnp.float32)
    pt = jnp.asarray(
        rng.permutation(P)[:B * n_pages].reshape(B, n_pages), jnp.int32)
    lens = jnp.full((B,), n_pages * page_size - 1, jnp.int32)
    k_new = jnp.asarray(rng.standard_normal((B, Hkv, Dh)), jnp.float32)
    v_new = jnp.asarray(rng.standard_normal((B, Hkv, Dh)), jnp.float32)
    return q, kp, vp, pt, lens, k_new, v_new


def _time_candidate(cand: Dict, inputs, interpret: bool, reps: int) -> float:
    q, kp, vp, pt, lens, k_new, v_new = inputs

    if cand["variant"] == "fused":
        def call():
            return paged_ops.fused_paged_decode_attention(
                q, kp, vp, pt, lens, k_new, v_new,
                pages_per_block=cand["pages_per_block"],
                grid_layout=cand["grid_layout"], interpret=interpret)[0]
    else:
        def call():
            return paged_ops.paged_decode_attention(
                q, kp, vp, pt, lens, variant=cand["variant"],
                pages_per_block=cand["pages_per_block"],
                grid_layout=cand["grid_layout"], interpret=interpret)

    call().block_until_ready()                       # compile + warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        call().block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def bench_rows(smoke: bool = False, reps: int = 3,
               shapes=None) -> List[Dict]:
    """Sweep every candidate over the benchmark shapes.  Each row
    carries tokens/s, achieved GB/s, and %-of-roofline — the quantities
    the nightly gate tracks (docs/BENCHMARKS.md)."""
    interp = interpret_mode()
    if shapes is None:
        shapes = SHAPES[:1] if smoke else SHAPES
    cands = CANDIDATES[:5] if smoke else CANDIDATES
    rows = []
    for (name, B, H, Hkv, Dh, page_size, n_pages) in shapes:
        inputs = _make_inputs(B, H, Hkv, Dh, page_size, n_pages)
        key = paged_ops.shape_key(page_size, Hkv, Dh, H // Hkv)
        for cand in cands:
            dt = _time_candidate(cand, inputs, interp, reps)
            bytes_hbm, flops = paged_decode_cost(
                B, H, Hkv, Dh, page_size, n_pages,
                fused=cand["variant"] == "fused")
            rows.append({
                "system": "kernel-bench", "shape": name, "shape_key": key,
                **cand,
                "time_s": round(dt, 6),
                "tokens_per_s": round(B / dt, 2),
                "achieved_gb_s": round(bytes_hbm / dt / 1e9, 3),
                "pct_of_roofline": round(
                    pct_of_roofline(dt, bytes_hbm, flops), 2),
                "interpret": interp,
            })
    return rows


def winners(rows: List[Dict]) -> Dict[str, Dict]:
    """Best candidate (highest tokens/s) per shape key."""
    best: Dict[str, Dict] = {}
    for r in rows:
        k = r["shape_key"]
        if k not in best or r["tokens_per_s"] > best[k]["tokens_per_s"]:
            best[k] = r
    return {k: {"variant": r["variant"],
                "pages_per_block": r["pages_per_block"],
                "grid_layout": r["grid_layout"]}
            for k, r in best.items()}


def persist_table(rows: List[Dict], path: Optional[str] = None) -> str:
    """Write the per-shape winners as the autotune table ops.py loads.

    Refuses interpret-mode measurements: a table tuned on interpreter
    timings would steer real hardware with noise.
    """
    bad = [r for r in rows if r.get("interpret")]
    if bad:
        raise RuntimeError(
            "refusing to persist autotune table: "
            f"{len(bad)}/{len(rows)} rows were measured under Pallas "
            "interpret mode (REPRO_PALLAS_INTERPRET=1 or no TPU "
            "backend).  Interpret timings measure the interpreter, not "
            "the kernel — re-run the sweep on TPU hardware without the "
            "override to regenerate the table.")
    if path is None:
        path = paged_ops._DEFAULT_TABLE
    table = {
        "_provenance": f"swept by benchmarks.kernel_bench on "
                       f"{jax.default_backend()} "
                       f"({len(rows)} measurements)",
        "configs": {"default": {"variant": "fused", "pages_per_block": 4,
                                "grid_layout": "bh"},
                    **winners(rows)},
    }
    with open(path, "w") as f:
        json.dump(table, f, indent=1)
        f.write("\n")
    return path


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="first shape + trimmed candidate grid")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--persist", action="store_true",
                    help="write winners into the checked-in autotune.json "
                         "(refused under interpret mode)")
    ap.add_argument("--out", default=None,
                    help="alternate table path for --persist")
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()

    rows = bench_rows(smoke=args.smoke, reps=args.reps)
    if args.json:
        print(json.dumps(rows, indent=1))
    else:
        for r in rows:
            print(f"{r['shape']:12s} {r['variant']:8s} "
                  f"ppb={r['pages_per_block']} {r['grid_layout']} "
                  f"{r['time_s'] * 1e3:8.3f} ms  {r['tokens_per_s']:10.1f} "
                  f"tok/s  {r['achieved_gb_s']:8.2f} GB/s  "
                  f"{r['pct_of_roofline']:6.2f}% SoL"
                  f"{'  [interpret]' if r['interpret'] else ''}")
    if args.persist:
        path = persist_table(rows, args.out)
        print(f"autotune table -> {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
