"""Table 4 — scheduler optimality: Random/RR/HEFT/Halo vs the oracle."""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import make_cm, setup
from repro.core import (BranchAndBoundOracle, EpochDPSolver, SCHEDULERS,
                        SolverConfig, optimality_score)
from repro.runtime import SimulatedProcessor


def run(n_queries: int = 256, workers: int = 3,
        workloads=("w1", "w6")) -> List[Dict]:
    rows = []
    for w in workloads:
        g, cons, _ = setup(w, n_queries)
        dag = g.llm_dag()
        cm = make_cm(g, cons)
        oracle = BranchAndBoundOracle(dag, make_cm(g, cons), workers,
                                      time_limit=120).solve()

        def simulate(plan):
            return SimulatedProcessor(g, make_cm(g, cons), workers).run(
                cons, plan)

        entries = {}
        for name in ("random", "rr", "heft"):
            fn = SCHEDULERS[name]
            plan = fn(dag, make_cm(g, cons), workers, 0) \
                if name == "random" else fn(dag, make_cm(g, cons), workers)
            entries[name] = plan
        t0 = time.perf_counter()
        solver = EpochDPSolver(dag, cm, SolverConfig(num_workers=workers))
        entries["halo"] = solver.solve()
        halo_solver_s = time.perf_counter() - t0
        entries["oracle"] = oracle.plan

        for name, plan in entries.items():
            rep = simulate(plan)
            rows.append({
                "workload": w, "scheduler": name,
                "e2e_latency_s": round(rep.makespan, 2),
                "opt": round(optimality_score(plan, oracle.plan, workers), 2),
                "solver_s": round(
                    halo_solver_s if name == "halo"
                    else oracle.solver_seconds if name == "oracle"
                    else plan.solver_seconds, 4),
            })
    return rows


if __name__ == "__main__":
    for r in run(64):
        print(r)
