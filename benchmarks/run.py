"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default sizes are CI-friendly (N=256); ``--full`` uses the paper's
N=1024.  Results land in experiments/results/*.json and a CSV summary
(`name,us_per_call,derived`) is printed per the harness convention.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks import (ablation, case_study, e2e_latency, online_serving,
                        optimality, scalability, sensitivity)

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "results")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale N=1024 (slower)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    n = 1024 if args.full else 256

    sections = {
        "e2e_latency_fig6": lambda: e2e_latency.run(n),
        "optimality_table4": lambda: optimality.run(min(n, 256)),
        "ablation_table5": lambda: ablation.run(min(n, 256)),
        "online_serving_fig7": lambda: online_serving.run(min(n, 128)),
        "scalability_fig8": lambda: scalability.run(),
        "sensitivity_fig9_10": lambda: sensitivity.run(n_queries=min(n, 256)),
        "case_study_fig11": lambda: case_study.run(n_queries=max(n, 1024)),
    }
    os.makedirs(OUT, exist_ok=True)
    print("name,us_per_call,derived")
    for name, fn in sections.items():
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        rows = fn()
        dt = time.perf_counter() - t0
        with open(os.path.join(OUT, f"{name}.json"), "w") as f:
            json.dump(rows, f, indent=1, default=str)
        derived = ""
        if name.startswith("e2e"):
            sp = [r["speedup_vs_halo"] for r in rows
                  if r["system"] in ("opwise", "langgraph", "agentscope",
                                     "parrot")]
            derived = f"max_speedup_vs_baselines={max(sp):.2f}x"
        elif name.startswith("optimality"):
            halo = [r for r in rows if r["scheduler"] == "halo"]
            derived = "opt=" + "/".join(str(r["opt"]) for r in halo)
        elif name.startswith("online"):
            derived = "halo_qps=" + "/".join(
                str(r["qps"]) for r in rows if r["system"] == "halo")
        elif name.startswith("case"):
            derived = f"gpu_seconds_reduction={rows[-1]['gpu_seconds_reduction']}x"
        print(f"{name},{dt * 1e6 / max(len(rows), 1):.0f},{derived}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
