"""Table 5 — component ablations on W1 and W6 (latency vs full Halo)."""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import halo_plan, make_cm, setup
from repro.core import EpochDPSolver, SolverConfig
from repro.runtime import SimulatedProcessor


def run(n_queries: int = 256, workers: int = 3,
        workloads=("w1", "w6")) -> List[Dict]:
    rows = []
    for w in workloads:
        g, cons, _ = setup(w, n_queries)
        dag = g.llm_dag()
        plan = halo_plan(g, cons, workers)

        def sim(cm=None, plan_=None, **kw):
            return SimulatedProcessor(
                g, cm or make_cm(g, cons), workers, **kw
            ).run(cons, plan_ or plan)

        full = sim()
        variants = {}
        # w/o profiling scoring: plan from naive dep-count cost model
        naive = EpochDPSolver(dag, make_cm(g, cons, use_profiling=False),
                              SolverConfig(num_workers=workers)).solve()
        variants["w/o profiling scoring"] = sim(plan_=naive)
        # w/o CPU load guidance: plan ignores T_prep
        blind = EpochDPSolver(dag, make_cm(g, cons, use_prep_guidance=False),
                              SolverConfig(num_workers=workers)).solve()
        variants["w/o cpu load guidance"] = sim(plan_=blind)
        # w/o opportunistic execution: static epoch-paced dispatch
        variants["w/o opportunistic exec"] = sim(
            opportunistic=False, barrier_mode=True)
        # w/o request coalescing
        variants["w/o request coalescing"] = sim(
            cm=make_cm(g, cons, logical_tools=True), coalescing=False)

        rows.append({"workload": w, "variant": "halo (full)",
                     "latency_s": round(full.makespan, 2), "delta": "1.00x"})
        for name, rep in variants.items():
            rows.append({
                "workload": w, "variant": name,
                "latency_s": round(rep.makespan, 2),
                "delta": f"{rep.makespan / full.makespan:.2f}x"})
    return rows


if __name__ == "__main__":
    for r in run(64):
        print(r)
