"""Fig. 7 — online serving throughput (QPS): Halo vs OpWise vs LangGraph.

``real_stream_rows`` streams micro-batches through REAL continuous-
batching engines with persistent hosts: later micro-batches land on the
warm KV pages of earlier ones, so the reported ``kv_tokens_reused`` /
``admission_waves`` show cross-batch cache sharing, not a model.
"""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import (engine_stat_cols, halo_plan, make_cm, setup)
from repro.core import consolidate, consolidate_multi, round_robin_plan
from repro.runtime import OnlineSimulator
from repro.workloads import build_mixed_workload

WORKLOADS = ("w1", "w3", "w5", "w+")


def _stream(g, cons, bindings, plan_fn, workers, micro_batch, rate,
            coalescing=True, barrier=False, kv_migration=True):
    batches = []
    for lo in range(0, len(bindings), micro_batch):
        cb = consolidate(g, bindings[lo:lo + micro_batch])
        batches.append((cb, plan_fn(cb)))
    sim = OnlineSimulator(
        g, make_cm(g, cons, logical_tools=not coalescing), workers,
        coalescing=coalescing, barrier_mode=barrier,
        opportunistic=not barrier, kv_migration=kv_migration)
    return sim.run(batches, rate)


def run(n_queries: int = 128, workers: int = 3, micro_batch: int = 16,
        rate_qps: float = 50.0) -> List[Dict]:
    rows = []
    for w in WORKLOADS:
        g, cons, bindings = setup(w, n_queries)
        plan = halo_plan(g, cons, workers)
        halo = _stream(g, cons, bindings, lambda cb: plan, workers,
                       micro_batch, rate_qps)
        opw = _stream(g, cons, bindings, lambda cb: plan, workers,
                      micro_batch, rate_qps, barrier=True,
                      kv_migration=False)
        cm_rr = make_cm(g, cons, logical_tools=True)
        rr = round_robin_plan(g.llm_dag(), cm_rr, workers)
        lang = _stream(g, cons, bindings, lambda cb: rr, workers,
                       micro_batch, rate_qps, coalescing=False,
                       kv_migration=False)
        for name, rep in (("halo", halo), ("opwise", opw),
                          ("langgraph", lang)):
            rows.append({"workload": w, "system": name,
                         "qps": round(rep.throughput_qps(), 3),
                         "makespan_s": round(rep.makespan, 1)})
    rows.extend(mixed_stream_rows(max(n_queries, 24), workers))
    return rows


def mixed_stream_rows(n_queries: int = 96, workers: int = 3,
                      micro_batch: int = 12,
                      rate_qps: float = 30.0) -> List[Dict]:
    """Mixed online arrivals (wd+wt+w4 interleaved): each micro-batch is
    consolidated into ONE mega-DAG instance (``consolidated-multi``) vs
    streaming every template through its own per-template pipeline
    (``per-template-serial``, makespans summed).  The realistic serving
    case the multi-template consolidator exists for: queries of
    different templates arrive interleaved and should share epochs,
    tool executions and warm KV (docs/BENCHMARKS.md)."""
    batches_full, _ = build_mixed_workload(n_queries, seed=0)
    mc_full = consolidate_multi(batches_full)
    g = mc_full.template
    per = max(micro_batch // max(len(batches_full), 1), 1)
    rounds = max((len(tb) + per - 1) // per
                 for _, tb in batches_full)
    stream = []
    for r in range(rounds):
        slices = [(tg, tb[r * per:(r + 1) * per])
                  for tg, tb in batches_full]
        mcr = consolidate_multi(slices)
        stream.append((mcr, halo_plan(mcr.template, mcr, workers)))
    sim = OnlineSimulator(g, make_cm(g, mc_full), workers)
    multi = sim.run(stream, rate_qps)

    serial_makespan = 0.0
    for tg, tb in batches_full:
        cons_t = consolidate(tg, tb)
        plan_t = halo_plan(tg, cons_t, workers)
        tstream = []
        for lo in range(0, len(tb), per):
            cb = consolidate(tg, tb[lo:lo + per])
            tstream.append((cb, plan_t))
        rep_t = OnlineSimulator(
            tg, make_cm(tg, cons_t), workers).run(tstream, rate_qps)
        serial_makespan += rep_t.makespan
    return [
        {"workload": "mixed", "system": "consolidated-multi",
         "qps": round(multi.throughput_qps(), 3),
         "makespan_s": round(multi.makespan, 1)},
        {"workload": "mixed", "system": "per-template-serial",
         "qps": round(n_queries / max(serial_makespan, 1e-9), 3),
         "makespan_s": round(serial_makespan, 1)},
    ]


def real_stream_rows(n_queries: int = 8, workers: int = 2,
                     micro_batch: int = 4, decode_cap: int = 3) -> List[Dict]:
    """Micro-batched arrival against real engines with persistent hosts
    AND a persistent OnlineOptimizer: later micro-batches run on warm KV
    pages and on a cost model calibrated by the earlier ones (replans
    fire when observed epoch cost drifts off the plan)."""
    from benchmarks.common import make_real_processor
    from repro.runtime import OnlineOptimizer
    from repro.runtime.executors import EngineHost
    proc, g, _, bindings, plan = make_real_processor(
        "w+", n_queries, workers, decode_cap)
    hosts = [EngineHost(proc.model_configs, seed=proc.seed)
             for _ in range(workers)]
    cm = make_cm(g, consolidate(g, bindings[:micro_batch]))
    opt = OnlineOptimizer(cm)      # run() rebinds cm to the capped graph
    t0 = time.perf_counter()
    rep = None
    replans = 0
    for lo in range(0, len(bindings), micro_batch):
        cb = consolidate(g, bindings[lo:lo + micro_batch])
        rep = proc.run(cb, plan, hosts=hosts,        # engines stay warm
                       optimizer=opt)
        replans += rep.extra["replans"]
    wall = time.perf_counter() - t0
    for h in hosts:
        h.shutdown()
    calib = opt.calibration_summary()
    return [{"workload": "w+", "system": "halo-real",
             "qps": round(n_queries / wall, 3),
             "makespan_s": round(wall, 1),
             **engine_stat_cols(rep),
             "replans": replans,
             "mfu_eff": round(calib["mfu_eff"], 5),
             "bw_eff_eff": round(calib["bw_eff_eff"], 5),
             "calib_samples": calib["samples"]}]


def _p95(xs: List[float]) -> float:
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(0.95 * len(xs)))]


def session_stream_rows(n_queries: int = 12, workers: int = 2,
                        decode_cap: int = 6, gap_s: float = 0.2,
                        latency_scale: float = 5.0) -> List[Dict]:
    """Streaming-session vs micro-batched A/B on warm real engines
    (DESIGN.md §10): a saturating batch lane (the first 2/3 of the
    queries) opens the run, then small interactive groups arrive every
    ``gap_s`` seconds while it is still decoding.

    * ``session-stream`` holds ONE ``ProcessorSession`` and grafts each
      arriving group into the running mega-DAG — interactive tool calls
      and prefills overlap the batch lane's decode;
    * ``micro-batched`` is the old regime — an arriving group waits for
      the in-flight run to drain before its own run starts.

    Both arms run the SAME queries on warm persistent hosts and must
    produce bitwise-identical temp-0 outputs (``outputs_match``); TTFT
    is measured per interactive query from its group's scheduled
    ARRIVAL time, so the baseline pays its batch-boundary queueing
    delay.  Each arm runs twice and only the second (steady-state) pass
    is reported: streaming admission composes decode batches whose
    shapes depend on arrival timing, so the first pass still pays JIT
    tracing the one-shot warm run cannot cover.  ``latency_scale``
    inflates the wt template's HTTP tool to real-API latencies — the
    cross-group CPU/GPU overlap a session exists to exploit."""
    from benchmarks.common import smoke_models_for
    from repro.runtime import ProcessorConfig, ProcessorSession
    from repro.runtime.executors import EngineHost
    from repro.workloads import build_workload
    from repro.workloads.datagen import build_database
    from repro.workloads.tools import ToolRuntime
    g, bindings, db = build_workload("wt", n_queries, seed=0)
    models = smoke_models_for(g)
    cfg = ProcessorConfig(num_workers=workers, decode_cap=decode_cap,
                          seed=0)
    lane = max(2 * n_queries // 3, 1)            # saturating batch lane
    tail = max((n_queries - lane) // 2, 1)       # interactive group size
    groups = [bindings[:lane]] + [bindings[lo:lo + tail]
                                  for lo in range(lane, n_queries, tail)]

    def norm(results, q_offset=0):
        out = {}
        for key, val in results.items():
            q, node = key.split(":", 1)
            base = node.split("/", 1)[1] if "/" in node else node
            out[(int(q) + q_offset, base)] = val
        return out

    def stream_pass(tools, hosts):
        ttfts, t0 = [], time.perf_counter()
        sess = ProcessorSession(models, tools, config=cfg)
        sess.open(hosts=hosts)
        try:
            for i, grp in enumerate(groups):
                arrival = t0 + i * gap_s
                time.sleep(max(0.0, arrival - time.perf_counter()))
                hs = sess.submit(
                    g, grp, slo="batch" if i == 0 else "interactive")
                if i > 0:
                    ttfts.append((arrival, hs))
            sess.drain(400)
            rep = sess.report()
        finally:
            sess.close()
        mk = time.perf_counter() - t0
        extra = {"grafts": rep.extra.get("grafts", 0),
                 "priority_jumps": rep.extra.get("priority_jumps", 0)}
        return mk, ttfts, norm(rep.results()), extra

    def micro_pass(tools, hosts):
        ttfts, outputs, offset = [], {}, 0
        t0 = time.perf_counter()
        for i, grp in enumerate(groups):
            arrival = t0 + i * gap_s
            time.sleep(max(0.0, arrival - time.perf_counter()))
            sess = ProcessorSession(models, tools, config=cfg)
            sess.open(hosts=hosts)           # previous run has drained
            try:
                hs = sess.submit(
                    g, grp, slo="batch" if i == 0 else "interactive")
                if i > 0:
                    ttfts.append((arrival, hs))
                sess.drain(400)
                outputs.update(norm(sess.report().results(),
                                    q_offset=offset))
            finally:
                sess.close()
            offset += len(grp)
        return time.perf_counter() - t0, ttfts, outputs, {}

    def run_arm(one_pass):
        tools = ToolRuntime(build_database(db),
                            latency_scale=latency_scale)
        hosts = [EngineHost(models, seed=cfg.seed) for _ in range(workers)]
        try:
            one_pass(tools, hosts)           # cold: JIT tracing
            one_pass(tools, hosts)           # converge arrival-timing shapes
            mk, ttfts, outputs, extra = one_pass(tools, hosts)
            p95 = _p95([h.first_result_at() - arrival
                        for arrival, hs in ttfts for h in hs])
            return mk, p95, outputs, extra
        finally:
            for h in hosts:
                h.shutdown()

    mk_s, p95_s, out_s, extra_s = run_arm(stream_pass)
    mk_b, p95_b, out_b, _ = run_arm(micro_pass)
    match = out_s == out_b and len(out_s) > 0
    return [
        {"workload": "wt", "system": "session-stream",
         "qps": round(n_queries / mk_s, 3), "makespan_s": round(mk_s, 3),
         "interactive_p95_ttft_s": round(p95_s, 3),
         "outputs_match": match, **extra_s},
        {"workload": "wt", "system": "micro-batched",
         "qps": round(n_queries / mk_b, 3), "makespan_s": round(mk_b, 3),
         "interactive_p95_ttft_s": round(p95_b, 3),
         "outputs_match": match},
    ]


if __name__ == "__main__":
    for r in run(64):
        print(r)
    for r in real_stream_rows():
        print(r)
    for r in session_stream_rows():
        print(r)
