"""Mixed multi-template batch → one mega-DAG → real engines.

Submits an interleaved wd+wt+w4 batch through ``consolidate_multi``
(DESIGN.md §8.1) and the real Processor, printing the coalescing
summary: which requests merged across templates, how many plan epochs
interleave macro-nodes of different templates, and the engine's
page-sharing counters.

    PYTHONPATH=src python examples/mixed_batch.py
"""
from repro.core import (EpochDPSolver, HARDWARE, PAPER_MODELS,
                        SolverConfig, CostModel, consolidate_multi)
from repro.runtime import ProcessorConfig, RealProcessor
from repro.workloads import build_mixed_workload
from repro.workloads.datagen import build_database
from repro.workloads.tools import ToolRuntime

# --- consolidate three templates' queries into ONE mega-DAG --------------
batches, db = build_mixed_workload(6, seed=0)      # wd + wt + w4, 2 each
mc = consolidate_multi(batches)
graph = mc.template
print("templates:", mc.template_names)
print("mega-DAG:", len(graph.nodes), "nodes /",
      len(graph.llm_nodes()), "LLM")

xt = mc.cross_template_summary()
print("cross-template:", xt)
for nid, row in sorted(mc.coalescing_summary().items()):
    if row["unique"] != row["physical"]:           # merged away
        print(f"  {nid}: {row}")

# --- plan it as one batch (epochs may interleave templates) --------------
cm = CostModel(graph, HARDWARE["h200"], PAPER_MODELS,
               batch_sizes={n: len(mc.macro(n).bindings)
                            for n in graph.nodes},
               warm_aliases=mc.warm_aliases())
plan = EpochDPSolver(graph.llm_dag(), cm,
                     SolverConfig(num_workers=2)).solve()
for i, e in enumerate(plan.epochs):
    tmpls = sorted({mc.template_of[v] for c in e.components for v in c})
    print(f"epoch {i}: {e.components} on workers {e.workers} "
          f"(templates {tmpls})")

# --- run it on real continuous-batching engines --------------------------
from benchmarks.common import smoke_models_for  # noqa: E402 (optional dep)

proc = RealProcessor(graph, smoke_models_for(graph),
                     ToolRuntime(build_database(db), latency_scale=0.0),
                     config=ProcessorConfig(num_workers=2, decode_cap=3))
report = proc.run(mc, plan)
print("makespan:", round(report.makespan, 2), "s")
print("coalesce:", report.coalesce_stats)
print("pages_shared:", report.extra["pages_shared"],
      "tokens_reused:", report.extra["tokens_reused"])
