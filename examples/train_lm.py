"""Train a ~100M-parameter LM for a few hundred steps (CPU-runnable).

    PYTHONPATH=src python examples/train_lm.py --steps 300

Exercises the full training substrate: AdamW + cosine schedule, remat,
grad accumulation, atomic checkpointing with resume, deterministic data.
The same train_step lowers onto the production meshes (launch/dryrun.py).
"""
import argparse

from repro.configs.base import ModelConfig
from repro.training import (AdamWConfig, DataConfig, TrainerConfig,
                            train_loop)

# ~100M params: 12 layers, d=768, tied embeddings over a 32k vocab
CFG_100M = ModelConfig(
    name="repro-100m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32768,
    head_dim=64, tie_embeddings=True, rope_theta=10000.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/halo_train_ckpt")
    args = ap.parse_args()

    print(f"model: {CFG_100M.param_count()/1e6:.0f}M params")
    tcfg = TrainerConfig(remat=True, grad_accum=2, adamw=AdamWConfig(
        lr=6e-4, warmup_steps=max(args.steps // 20, 10),
        total_steps=args.steps))
    dcfg = DataConfig(vocab_size=CFG_100M.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, structure=0.85)
    out = train_loop(CFG_100M, tcfg, dcfg, num_steps=args.steps,
                     ckpt_dir=args.ckpt_dir, ckpt_every=100,
                     log_every=max(args.steps // 30, 1))
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({out['seconds']:.0f}s); checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
