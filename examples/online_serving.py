"""Online serving: streaming arrivals, overlapping plan instances.

    PYTHONPATH=src python examples/online_serving.py

Streams 96 queries of W3 at 4 QPS into micro-batches of 16, with
cross-instance result caching (DB results fetched by earlier batches are
reused by later ones) — then injects a mid-run worker failure and shows
the run still completing via plan redistribution.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import consolidate
from repro.runtime import OnlineSimulator
from repro.runtime.simulator import ClusterSimulator
from benchmarks.common import halo_plan, make_cm, setup


def main():
    g, cons, bindings = setup("w3", 96)
    plan = halo_plan(g, cons, 3)
    batches = [(consolidate(g, bindings[lo:lo + 16]), plan)
               for lo in range(0, 96, 16)]

    rep = OnlineSimulator(g, make_cm(g, cons), 3).run(batches, 4.0)
    print("online:", rep.summary())
    print(f"sustained {rep.throughput_qps():.2f} QPS over "
          f"{rep.makespan:.1f}s; tool dedup "
          f"{rep.coalesce_stats['tool_dedup_ratio']:.2f} "
          f"(cross-instance caching included)")

    # ---- fault tolerance: kill worker 1 a third of the way in ----------
    sim = ClusterSimulator(g, make_cm(g, cons), 3)
    for cb, p in batches:
        sim.add_instance(cb, p, arrival=0.0)
    sim.add_failure(rep.makespan * 0.3, worker=1)
    rep2 = sim.run()
    done = len({(r.instance, r.node) for r in rep2.records if r.kind == "llm"})
    print(f"\nwith worker-1 failure at t={rep.makespan*0.3:.1f}s: "
          f"completed {done} LLM macro-nodes across "
          f"{len(batches)} instances in {rep2.makespan:.1f}s "
          f"(failure event: {rep2.extra})")


if __name__ == "__main__":
    main()
