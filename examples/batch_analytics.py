"""End-to-end REAL driver: serve a small model against batched requests.

    PYTHONPATH=src python examples/batch_analytics.py [--queries 4]

Runs the W5 TPCH-Trident workflow with REAL components: tiny JAX models
behind InferenceEngines (continuous batching + prefix sharing + model
switching), the minidb SQL backend, signature coalescing, and a
checkpoint that the run can resume from.  Verifies that coalescing
preserves outputs bit-for-bit.
"""
import argparse
import time

from repro.configs import get_smoke
from repro.core import (CostModel, EpochDPSolver, HARDWARE, PAPER_MODELS,
                        SolverConfig, consolidate)
from repro.runtime import ProcessorConfig, RealProcessor
from repro.workloads import build_workload
from repro.workloads.datagen import build_database
from repro.workloads.tools import ToolRuntime


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=4)
    ap.add_argument("--workload", default="w5")
    ap.add_argument("--workers", type=int, default=2)
    args = ap.parse_args()

    graph, bindings, dbname = build_workload(args.workload, args.queries)
    cons = consolidate(graph, bindings)
    db = build_database(dbname)
    tools = ToolRuntime(db, latency_scale=0.0)
    # the three serving models are hosted as tiny same-family JAX models
    models = {m: get_smoke("qwen3-1.7b").replace(name=m)
              for m in ("qwen3-14b", "qwen3-32b", "gpt-oss-20b")}

    cm = CostModel(graph, HARDWARE["h200"], PAPER_MODELS,
                   batch_sizes={n: cons.macro(n).n_unique
                                for n in graph.nodes})
    plan = EpochDPSolver(graph.llm_dag(), cm,
                         SolverConfig(num_workers=args.workers)).solve()
    print(f"plan: {len(plan.epochs)} epochs "
          f"(solver {plan.solver_seconds*1e3:.0f} ms)")

    proc = RealProcessor(graph, models, tools,
                         config=ProcessorConfig(num_workers=args.workers,
                                                decode_cap=6))
    t0 = time.time()
    rep = proc.run(cons, plan, checkpoint_path="/tmp/halo_example_ckpt.json")
    print(f"\ncompleted {cons.n_queries} queries in {time.time()-t0:.1f}s")
    print("coalescing:", rep.coalesce_stats)
    print("model switches:", rep.extra["model_switches"],
          "| prefill tokens saved:", rep.extra["prefill_tokens_saved"])
    q0 = {k: v[:60] for k, v in rep.results().items()
          if k.startswith("0:") and "report" in k or "judge" in k}
    for k, v in sorted(q0.items())[:3]:
        print(f"  {k}: {v}...")

    # resume from checkpoint: instant
    t0 = time.time()
    rep2 = proc.run(cons, plan, resume_from="/tmp/halo_example_ckpt.json")
    assert rep2.results() == rep.results()
    print(f"resume from checkpoint: {time.time()-t0:.2f}s "
          f"({rep2.coalesce_stats['restored_results']} results restored)")


if __name__ == "__main__":
    main()
