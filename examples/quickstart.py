"""Quickstart: Halo in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Declares a 4-agent workflow, binds a batch of 64 queries, plans with the
epoch DP, and simulates against the OpWise baseline.
"""
from repro.core import (CostModel, EpochDPSolver, HARDWARE, PAPER_MODELS,
                        SolverConfig, consolidate, parse_workflow)
from repro.runtime import OpWiseSimulator, SimulatedProcessor

workflow = {
    "name": "revenue-investigation",
    "nodes": [
        {"id": "search", "type": "llm", "model": "qwen3-14b",
         "prompt": "Summarize {{sql: SELECT sum(quantity) FROM lineitem "
                   "WHERE shipdate <= '$date'}} for $market",
         "max_new_tokens": 48, "est_prompt_tokens": 192},
        {"id": "analyze", "type": "llm", "model": "qwen3-32b",
         "prompt": "Attribute the revenue change in ${search}.",
         "max_new_tokens": 64, "est_prompt_tokens": 256},
        {"id": "connect", "type": "llm", "model": "gpt-oss-20b",
         "prompt": "Correlate {{http: GET /news?m=$market}} with ${search}.",
         "max_new_tokens": 48, "est_prompt_tokens": 256},
        {"id": "edit", "type": "llm", "model": "qwen3-32b",
         "prompt": "Write the final report from ${analyze} and ${connect}.",
         "max_new_tokens": 96, "est_prompt_tokens": 384},
    ],
}

graph = parse_workflow(workflow)                       # §3 Parser
print("nodes:", graph.topo_order())

bindings = [{"market": m, "date": f"199{d}-06-01"}
            for m in ("us", "eu", "apac", "latam") for d in range(4)] * 4
cons = consolidate(graph, bindings)                    # 64 queries
print("coalescing:", cons.coalescing_summary())

batch = {n: (cons.macro(n).n_logical if graph.nodes[n].is_llm()
             else cons.macro(n).n_unique) for n in graph.nodes}
cm = CostModel(graph, HARDWARE["h200"], PAPER_MODELS, batch_sizes=batch)
plan = EpochDPSolver(graph.llm_dag(), cm,
                     SolverConfig(num_workers=3)).solve()   # §4 Optimizer
print(f"\nplan ({plan.solver_seconds*1e3:.1f} ms solve):")
for e in plan.epochs:
    print("  epoch:", list(zip(e.components, e.workers)))

halo = SimulatedProcessor(graph, cm, 3).run(cons, plan)     # §5 Processor
opwise = OpWiseSimulator(graph, cm, 3).run(cons)
print(f"\nhalo   : {halo.makespan:6.1f}s  {halo.summary()}")
print(f"opwise : {opwise.makespan:6.1f}s  (x{opwise.makespan/halo.makespan:.2f})")
